"""Coordinator logic shared by every execution backend.

The coordinator owns the global iterate ``x``, applies worker returns in
arrival order (with fault filtering), fires Anderson/DIIS with the Eq. 5
safeguard, records the residual history, and assembles the
:class:`~repro.core.engine.types.RunResult`.  Backends differ only in *how*
worker evaluations are scheduled (virtual event queue vs real threads); the
apply/accel/record path below is byte-for-byte the behaviour of the
pre-refactor monolithic engine, so fixed-seed virtual-time runs stay
bit-identical.

Evaluation pipeline
-------------------
The accel/record path is a *pure state machine* so its expensive
evaluations (the full map at the fire's pinned iterate, the Eq. 5
safeguard residual norms, the residual-history records) can run anywhere:

- :meth:`Coordinator.accel_begin` pins the current iterate and emits the
  first :class:`EvalItem`; :meth:`Coordinator.accel_feed` consumes one
  evaluated item and emits the next (the safeguard residuals appear only
  when there is a candidate to judge); :meth:`Coordinator.accel_commit`
  applies the accept/reject verdict against the *live* iterate — guarded
  by ``cfg.accel_stale_limit``: a fire whose evaluations took too many
  applied arrivals to come back is discarded rather than allowed to
  overwrite fresher blocks.
- :meth:`Coordinator.record_begin` / :meth:`Coordinator.record_commit`
  give residual-history evaluations the same treatment.

:meth:`maybe_fire_accel` (the inline, coordinator-evaluated path every
sync loop and the default async mode use) drives exactly this machine with
immediate local evaluations, which keeps it bit-identical to the
pre-split code.  Backends running with ``cfg.accel_eval == "worker"``
drive it with offloaded evaluations instead — their EvalService — so
fires and records overlap with arrivals.

Elastic membership (repro.chaos)
--------------------------------
The coordinator also owns the worker -> blocks assignment.  Statically it
is the identity (block ``w`` served by worker ``w``, the pre-chaos
behaviour, bit-identical); chaos scenarios move it: ``preempt_worker``
rebalances a leaver's blocks onto the least-loaded survivors,
``join_worker`` hands the home block back, ``next_dispatch`` walks a
worker's assignment round-robin, and ``preempt_gen`` lets backends
recognize (and discard) results computed by a preempted incarnation.
``accel_commit``'s staleness guard doubles as the reassignment-window
guard: a fire spanning a membership change is discarded.
"""

from __future__ import annotations

import time
from dataclasses import replace as _dc_replace
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from ..anderson import AndersonState
from ..fixedpoint import FixedPointProblem, as_block_slice, restrict
from .types import FaultProfile, RunConfig, RunResult, _fault_for, _writable

__all__ = [
    "Coordinator",
    "EvalItem",
    "AccelPlan",
    "RecordPlan",
    "worker_eval",
    "measure_compute",
    "warm_problem",
    "problem_payload",
    "rebuild_problem",
]


def measure_compute(problem: FixedPointProblem, blocks: Sequence[np.ndarray]) -> float:
    """Measure per-update compute cost of a representative block (warm jit)."""
    idx = blocks[0]
    problem.block_update(problem.initial(), idx)  # warm-up / compile
    x = problem.initial()
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        problem.block_update(x, idx)
    return max((time.perf_counter() - t0) / reps, 1e-7)


def worker_eval(
    problem: FixedPointProblem, cfg: RunConfig, x_snapshot: np.ndarray,
    indices: np.ndarray,
) -> np.ndarray:
    """The worker computation (on its stale snapshot)."""
    if cfg.return_mode == "full_map":
        return restrict(np.asarray(problem.full_map(x_snapshot)), indices)
    return np.asarray(problem.block_update(x_snapshot, indices))


def warm_problem(problem: FixedPointProblem, cfg: RunConfig,
                 worker: Optional[int] = None,
                 blocks: Optional[Sequence[np.ndarray]] = None) -> None:
    """Compile every jit specialization a run's dispatches will hit.

    Real backends call this before starting the clock so compile time never
    skews measured wall-clock.  ``worker=None`` warms all workers' block
    shapes (single-interpreter backends: thread); an int warms only that
    worker's own block (per-interpreter workers — process, ray — each warm
    themselves).  Selection warming uses plain aranges of the exact index-
    set sizes the run will produce, leaving the coordinator rng untouched.

    ``blocks`` lets callers pass the partition the run will actually
    dispatch (the coordinator memoizes it at construction); when omitted it
    is re-derived from the problem's defaults.
    """
    x0 = problem.initial()
    if blocks is None:
        blocks = problem.default_blocks(cfg.n_workers)
    for blk in (blocks if worker is None else [blocks[worker]]):
        worker_eval(problem, cfg, x0, blk)
    if cfg.accel_eval == "worker":
        # Offloaded evaluation pipeline: workers also serve full-map and
        # residual-norm items, so those jit specializations must be warm.
        problem.full_map(x0)
        problem.residual_norm(x0)
    if cfg.selection != "fixed":
        k = cfg.selection_k or max(1, problem.n // cfg.n_workers)
        sizes = {min(k, problem.n)}
        if cfg.mode == "sync":
            total = min(cfg.n_workers * k, problem.n)
            sizes = {len(c) for c in
                     np.array_split(np.arange(total), cfg.n_workers)}
        for sz in sizes:
            if sz:
                worker_eval(problem, cfg, x0, np.arange(sz))


def problem_payload(problem: FixedPointProblem):
    """Picklable recipe for rebuilding ``problem`` in another interpreter.

    Prefers ``factory_spec()``; falls back to pickling the instance itself
    (fine for plain-numpy problems).  Raises with a pointer to
    ``factory_spec`` if neither works.
    """
    spec = problem.factory_spec()
    if spec is not None:
        return ("factory", spec)
    import pickle

    try:
        pickle.dumps(problem)
    except Exception as e:
        raise ValueError(
            f"{type(problem).__name__} cannot cross process boundaries: it "
            f"does not pickle ({e!r}) and defines no factory_spec(). "
            "Implement FixedPointProblem.factory_spec() returning "
            "(factory, args, kwargs)."
        ) from e
    return ("pickle", problem)


def rebuild_problem(payload) -> FixedPointProblem:
    kind, data = payload
    if kind == "factory":
        factory, args, kwargs = data
        return factory(*args, **kwargs)
    return data


class _BusyTimer:
    """Re-entrant-enough timer behind :meth:`Coordinator.busy` (each enter
    opens its own interval; backends never nest them)."""

    __slots__ = ("_coord", "_t0")

    def __init__(self, coord: "Coordinator"):
        self._coord = coord
        self._t0 = 0.0

    def __enter__(self) -> "_BusyTimer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._coord.busy_s += time.perf_counter() - self._t0


# --------------------------------------------------------------------- #
# Evaluation pipeline work items / plans
# --------------------------------------------------------------------- #
class EvalItem:
    """One evaluation the accel/record pipeline needs.

    ``kind`` is ``"full_map"`` (evaluate ``G`` at ``x``, returns an array)
    or ``"res_norm"`` (``problem.residual_norm(x)``, returns a float).
    Items are backend-agnostic: the coordinator evaluates them inline via
    :meth:`Coordinator.eval_item`, the real backends ship ``x`` to a worker
    (shared-memory slot, object store, pool thread) and feed the value back.
    """

    __slots__ = ("kind", "x")
    FULL_MAP = "full_map"
    RES_NORM = "res_norm"

    def __init__(self, kind: str, x: np.ndarray):
        self.kind = kind
        self.x = x


# Below this iterate size an eager pin copy costs less than the lock
# round-trip a deferred (copy-on-write) materialization forces on the
# fire path: the opener already holds the backend lock at accel_begin,
# while a lazy pin makes the eval thread queue for the contended lock
# before its first evaluation — dead time that counts against the
# staleness guard.  Lazy pins pay off once copying all of x under the
# lock is the bigger stall.
LAZY_PIN_MIN_N = 1 << 16


class AccelPlan:
    """State of one in-flight Anderson/DIIS fire (begin -> feed* -> commit).

    Pins the iterate and applied-update count at ``accel_begin`` so the
    pipeline's evaluations are well-defined even while arrivals keep
    landing; ``next_item()`` is an idempotent peek at the evaluation the
    plan currently needs (None once the verdict is decided and the plan is
    ready for :meth:`Coordinator.accel_commit`).
    """

    __slots__ = ("x_pin", "wu_begin", "t_begin", "mver", "stage", "g", "cand",
                 "cur_res", "verdict", "done", "_item", "_pin_lazy",
                 "_pin_saves", "_tel_t0")

    def __init__(self, x_pin: np.ndarray, wu_begin: int, t_begin: float,
                 mver: int = 0):
        self.x_pin = x_pin
        self.wu_begin = wu_begin
        self.t_begin = t_begin
        self._tel_t0 = t_begin  # telemetry fire-span open (recorder clock)
        self.mver = mver  # membership version at begin (reassignment guard)
        # Copy-on-write pin (accel_begin(pin="lazy")): while True, x_pin is
        # the *live* iterate and _pin_saves holds the (indices, old values)
        # of every block overwritten since begin; materialize_pin replays
        # them onto a copy to reconstruct the begin-time snapshot.
        self._pin_lazy = False
        self._pin_saves: List[Tuple[object, np.ndarray]] = []
        self.stage = "map"  # "map" -> ("cur" -> "cand")? -> done
        self.g: Optional[np.ndarray] = None
        self.cand: Optional[np.ndarray] = None
        self.cur_res: Optional[float] = None
        self.verdict: Optional[str] = None  # "accept" | "fallback"
        self.done = False
        self._item: Optional[EvalItem] = EvalItem(EvalItem.FULL_MAP, x_pin)

    def next_item(self) -> Optional[EvalItem]:
        return self._item


class RecordPlan:
    """One in-flight residual-history record (begin -> commit).

    The residual is evaluated at the iterate pinned at ``record_begin``;
    the history entry keeps the begin-time ``(t, wu)`` coordinates, so an
    offloaded record is the residual *of that moment*, delivered late.
    """

    __slots__ = ("t", "wu", "x_version", "done", "_item")

    def __init__(self, x_pin: np.ndarray, wu: int, t: float, x_version: int):
        self.t = t
        self.wu = wu
        self.x_version = x_version
        self.done = False
        self._item: Optional[EvalItem] = EvalItem(EvalItem.RES_NORM, x_pin)

    def next_item(self) -> Optional[EvalItem]:
        return self._item


class Coordinator:
    """Shared coordinator state and apply/accel/record logic."""

    def __init__(self, problem: FixedPointProblem, cfg: RunConfig):
        if cfg.accel_eval not in ("coordinator", "worker"):
            raise ValueError(
                f"unknown accel_eval {cfg.accel_eval!r}; "
                "expected 'coordinator' or 'worker'")
        if (cfg.scenario is not None or cfg.capture_trace
                or cfg.controller is not None):
            # Chaos scenarios / trace replay / autoscale controllers pin the
            # dispatch schedule to the memoized block partition and to
            # inline (coordinator-side) accel evaluation; see repro.chaos
            # and repro.autoscale.
            if cfg.selection != "fixed":
                raise ValueError(
                    "chaos scenarios, trace capture and controllers require "
                    f"selection='fixed' (got {cfg.selection!r})")
            if cfg.eval_time is not None:
                raise ValueError(
                    "chaos scenarios / trace capture / controllers do not "
                    "compose with the virtual eval-cost model "
                    "(cfg.eval_time)")
        if cfg.capture_trace and cfg.mode == "sync":
            raise ValueError(
                "capture_trace records async schedules only (a sync run is "
                "already reproducible from its round plan)")
        if cfg.checkpoint_every is not None:
            if cfg.checkpoint_every < 1:
                raise ValueError(
                    f"checkpoint_every must be >= 1 (got {cfg.checkpoint_every})")
            if not cfg.checkpoint_dir:
                raise ValueError(
                    "checkpoint_every requires checkpoint_dir (where the "
                    "SolveCheckpoint JSON + npz files land)")
            if cfg.mode != "async":
                raise ValueError(
                    "checkpointing covers async solves only (a sync run is "
                    "already reproducible from its round plan)")
            if cfg.accel_eval == "worker" or cfg.eval_time is not None:
                # Arrival boundaries are the consistency points; offloaded
                # fires / the eval-cost model keep evaluation plans in
                # flight across them, so a snapshot there is not consistent.
                raise ValueError(
                    "checkpointing requires accel_eval='coordinator' and no "
                    "eval_time (in-flight offloaded evaluations cannot be "
                    "checkpointed)")
        if cfg.resume_from is not None:
            if cfg.scenario is not None or cfg.controller is not None \
                    or cfg.capture_trace:
                raise ValueError(
                    "a resumed run cannot re-attach a scenario, controller "
                    "or trace capture (their state died with the control "
                    "plane); use repro.recover.resume_fixed_point, which "
                    "strips them")
            if cfg.mode != "async":
                raise ValueError("resume_from covers async solves only")
        if cfg.scenario is not None or cfg.controller is not None:
            if cfg.accel_eval == "worker" and cfg.executor == "virtual":
                # Thread/process/ray run offloaded fires through a real
                # eval service and commit them restricted to blocks whose
                # ownership did not move; the virtual chaos event loop
                # evaluates fires inline only.
                raise ValueError(
                    "chaos scenarios with accel_eval='worker' need a real "
                    "backend (thread/process/ray); the virtual chaos loop "
                    "evaluates fires coordinator-side")
            validate = getattr(cfg.scenario, "validate", None)
            if validate is not None:
                validate(cfg.n_workers)
        self.problem = problem
        self.cfg = cfg
        self.x = _writable(problem.initial())
        self.rng = np.random.default_rng(cfg.seed)
        self.wu = 0
        self.drops = 0
        self.stale_drops = 0
        self.crashes = 0
        self.restarts = 0
        self.staleness_sum = 0
        self.staleness_n = 0
        self.history: List[Tuple[float, int, float]] = []
        self.accel: Optional[AndersonState] = (
            AndersonState(cfg.accel) if cfg.accel is not None else None
        )
        self.blocks = problem.default_blocks(cfg.n_workers)
        # Hot-path bookkeeping: identity projections skip the per-arrival
        # project/copy round trip entirely, and the memoized partition's
        # consecutive blocks are written through slices (one memcpy) rather
        # than integer fancy indexing.  Keyed by id(): the block arrays are
        # owned by this coordinator for its whole lifetime, and arrivals
        # hand back the very same objects.
        self._trivial_project = bool(problem.is_projection_trivial())
        self._block_slices = {}
        for blk in self.blocks:
            sl = as_block_slice(blk)
            if sl is not None:
                self._block_slices[id(blk)] = sl
        self.res_norm = problem.residual_norm(self.x)
        self.record_every = cfg.record_every or cfg.n_workers
        self.max_arrivals = (
            cfg.max_arrivals if cfg.max_arrivals is not None
            else 10 * cfg.max_updates
        )
        self.coordinator_evals = 0
        self.arrivals = 0  # worker returns seen (applied, dropped or crashed)
        self.since_record = 0  # arrivals since the last residual check
        # --- evaluation pipeline bookkeeping --------------------------- #
        self.offloaded_evals = 0
        self.accel_discards = 0
        self.busy_s = 0.0  # coordinator-occupied time (backend clock)
        self.fire_window_s = 0.0
        self.fire_window_arrivals = 0
        # Real backends flip this on so inline fires measure their blocking
        # window with perf_counter; the virtual backend keeps it off — its
        # clock is virtual seconds, and mixing nondeterministic wall time
        # into a fixed-seed RunResult would break reproducibility (its
        # eval-cost model charges modeled time through accel_commit instead).
        self.measure_fire_windows = False
        self._fires_inflight = 0
        # --- pin bookkeeping (accel_begin pin modes) ------------------- #
        # Lazy (copy-on-write) pins registered here get their overwritten
        # blocks saved by apply_return until materialize_pin reconstructs
        # the begin-time snapshot; _x_spare recycles the buffer a full
        # accel commit displaces so materialization reuses it instead of
        # allocating a fresh O(n) array every fire.
        self._pin_watch: List[AccelPlan] = []
        self._x_spare: Optional[np.ndarray] = None
        self.pin_copies_avoided = 0
        self.pin_cow_saves = 0
        # --- device-resident data plane (cfg.device_plane) ------------- #
        # Freshness signals for backends keeping blocks device-resident: a
        # worker's resident block mirrors x[block] iff its own last apply
        # was verbatim (no damping/noise/corruption rewrote the values)
        # and no accel commit has rewritten x since (commit_version).
        self.commit_version = 0
        self.last_apply_verbatim = False
        self.device_dispatches = 0
        self.device_refreshes = 0
        # Last fused block-local residual norm per worker (a convergence
        # proxy for observability; the recorded history stays the true
        # full residual).
        self.device_local_norms: dict = {}
        self._accel_stale_limit = (
            cfg.accel_stale_limit if cfg.accel_stale_limit is not None
            else 4 * cfg.n_workers
        )
        # Residual-staleness tracking: _x_version bumps on every mutation
        # of x; result() may reuse self.res_norm iff nothing moved since it
        # was evaluated (saves the redundant full map the old code paid).
        self._x_version = 0
        self._res_version = 0
        # --- elastic membership (repro.chaos scenarios) ----------------- #
        # The block partition is fixed; the worker -> blocks assignment is
        # not.  Initially block w is served by worker w; a preemption
        # reassigns the leaver's blocks to the least-loaded survivors and
        # a join hands the home block back.  Static-membership runs never
        # touch any of this, so the default paths stay bit-identical.
        p = cfg.n_workers
        self.active: set = set(range(p))  # workers currently in membership
        self.paused: set = set()  # in membership but not taking new work
        self.worker_blocks: dict = {w: [w] for w in range(p)}
        self.block_owner: dict = {b: b for b in range(len(self.blocks))}
        self._orphan_blocks: list = []  # blocks with no live server
        self._rr: dict = {w: 0 for w in range(p)}  # multi-block round-robin
        self.preempt_gen: dict = {w: 0 for w in range(p)}
        self.preemptions = 0
        self.joins = 0
        self.reassigned_blocks = 0
        self.preempt_discards = 0
        self.applied_by_worker: dict = {}
        self._membership_version = 0
        # block -> membership version at which its ownership last changed
        # (orphaning counts).  Lets accel_commit() restrict an offloaded
        # fire whose begin->commit window crossed a preempt/join to the
        # blocks that did not move, instead of discarding it wholesale.
        self._block_moved_at: dict = {}
        self.accel_partial_commits = 0
        # Scenario set_profile overrides (worker -> live FaultProfile); the
        # base profiles from cfg.faults apply where there is no override.
        self.live_profiles: dict = {}
        # Trace recorder (repro.chaos.TraceRecorder), set by backends when
        # cfg.capture_trace; record/fire/offload/scenario events are
        # emitted from the coordinator so every loop captures them in
        # arrival order for free.
        self.tracer = None
        # --- closed-loop autoscaling (repro.autoscale) ------------------ #
        # Workers removed by *scripted* preemptions: their infrastructure
        # is gone until the script joins them back, so a controller may
        # never "resurrect" them (controller_admissible).  Maintained by
        # apply_scenario_event's source tag; controller-initiated
        # preemptions (voluntary shedding) do not land here.
        self.scenario_down: set = set()
        self.controller_actions = 0
        # --- durable solves (repro.recover) ----------------------------- #
        # SDC guard state: a sliding window of accepted update norms is the
        # divergence baseline; per-worker strike counts feed the k-strikes
        # quarantine.  All of it is inert (and rng-free) when
        # cfg.sdc_guard is off, so default paths stay bit-identical.
        self.sdc_rejects = 0
        self.quarantined = 0
        self._sdc_norms: List[float] = []
        self._sdc_strikes: dict = {}
        self._sdc_block_rejects: dict = {}  # block key -> consecutive rejects
        # Checkpoint bookkeeping: backends call maybe_checkpoint at arrival
        # boundaries; _last_ckpt_wu stops a wu that stalls on drops from
        # re-writing the same checkpoint.
        self.checkpoints_written = 0
        self.resumed_from: Optional[str] = None
        self._last_ckpt_wu = -1
        self.probe = None
        if cfg.controller is not None:
            from ...autoscale.signals import SignalProbe  # lazy: optional

            cfg.controller.reset(cfg)
            self.probe = SignalProbe(cfg, p, self._accel_stale_limit,
                                     cfg.controller)
        # --- unified telemetry plane (repro.telemetry) ------------------ #
        # Span/series recorder, None by default: every hook below is one
        # `is not None` guard, and the recorder consumes no rng and never
        # touches iterate floats, so runs are bit-identical off *or* on.
        self.telemetry = None
        if cfg.telemetry:
            from ...telemetry import (  # lazy: keep the default import light
                TelemetryRecorder, as_telemetry_config)

            self.telemetry = TelemetryRecorder(
                as_telemetry_config(cfg.telemetry),
                meta={"executor": cfg.executor, "mode": cfg.mode,
                      "n_workers": p, "seed": cfg.seed,
                      "accel": cfg.accel is not None,
                      "accel_eval": cfg.accel_eval},
                n_workers=p)
            if self.probe is not None:
                # One staleness window for both planes: the probe reads
                # the recorder's buffer instead of keeping its own.
                self.probe.attach_telemetry(self.telemetry)

    # ----------------------------------------------------------------- #
    def busy(self):
        """Context manager accumulating coordinator-occupied wall time.

        Real backends wrap their coordinator-side sections (apply, inline
        fires, commits) with it; ``RunResult.coordinator_busy_frac`` is the
        accumulated time over the run's wall clock.  The virtual backend's
        eval-cost loop charges modeled virtual seconds into ``busy_s``
        directly instead.
        """
        return _BusyTimer(self)

    # ----------------------------------------------------------------- #
    # Elastic membership (repro.chaos scenarios)
    # ----------------------------------------------------------------- #
    def fault_for(self, worker: int) -> FaultProfile:
        """The worker's *live* fault profile: a scenario ``set_profile``
        override when one is in effect, else the static ``cfg.faults``."""
        prof = self.live_profiles.get(worker)
        return prof if prof is not None else _fault_for(self.cfg, worker)

    def preempt_worker(self, worker: int) -> int:
        """Remove a worker from the membership; rebalance its blocks onto
        the least-loaded survivors.  Returns the number of blocks moved.
        In-flight results from the old incarnation are recognized (and
        discarded) through ``preempt_gen``."""
        if worker not in self.active:
            return 0
        self.active.discard(worker)
        self.paused.discard(worker)
        self.preemptions += 1
        self.preempt_gen[worker] += 1
        moved = self.worker_blocks.get(worker, [])
        self.worker_blocks[worker] = []
        survivors = sorted(self.active)
        if not survivors:
            self._orphan_blocks.extend(moved)
        else:
            for b in moved:
                tgt = min(survivors,
                          key=lambda s: (len(self.worker_blocks[s]), s))
                self.worker_blocks[tgt].append(b)
                self.block_owner[b] = tgt
            self.reassigned_blocks += len(moved)
        self._membership_version += 1
        for b in moved:
            self._block_moved_at[b] = self._membership_version
        return len(moved)

    def join_worker(self, worker: int) -> int:
        """(Re)admit a worker: it takes back its home block (plus any
        orphaned blocks).  Returns the number of blocks it received."""
        if worker in self.active:
            return 0
        self.active.add(worker)
        self.joins += 1
        self.worker_blocks.setdefault(worker, [])
        back = list(self._orphan_blocks)
        self._orphan_blocks = []
        home = worker if worker in self.block_owner else None
        if (home is not None and home not in back
                and self.block_owner[home] != worker):
            holder = self.block_owner[home]
            if home in self.worker_blocks.get(holder, []):
                self.worker_blocks[holder].remove(home)
            back.append(home)
        for b in back:
            self.block_owner[b] = worker
            self.worker_blocks[worker].append(b)
        self.reassigned_blocks += len(back)
        self._membership_version += 1
        for b in back:
            self._block_moved_at[b] = self._membership_version
        return len(back)

    def dispatchable(self, worker: int) -> bool:
        """True when the worker may be handed new work right now."""
        return (worker in self.active and worker not in self.paused
                and bool(self.worker_blocks.get(worker)))

    def apply_scenario_event(self, ev, t: float = 0.0,
                             source: str = "script") -> None:
        """Apply one :class:`repro.chaos.ScenarioEvent` to the membership /
        live-profile state.  Backend-specific plumbing (waking parked
        threads, re-dispatching joined workers, pushing profiles into
        worker processes) stays in the backends.

        ``source`` distinguishes scripted events from controller actions
        (``"controller"``): scripted preemptions mark the worker
        ``scenario_down`` — its infrastructure is gone until the script
        joins it back — while controller preemptions are voluntary
        shedding the controller may undo.  Both apply through the same
        idempotent membership primitives, which is what lets scripts and
        controllers compose without double-applying anything.
        """
        if self.probe is not None:
            # Worker-seconds meter: charge the segment that ends here at
            # the membership size that held during it.
            self.probe.accumulate(len(self.active - self.paused), t)
        if source == "script":
            if ev.kind == "preempt":
                self.scenario_down.add(ev.worker)
            elif ev.kind == "join":
                self.scenario_down.discard(ev.worker)
        if ev.kind == "set_profile":
            targets = ([ev.worker] if ev.worker is not None
                       else range(self.cfg.n_workers))
            for w in targets:
                self.live_profiles[w] = ev.profile
        elif ev.kind == "preempt":
            self.preempt_worker(ev.worker)
        elif ev.kind == "join":
            self.join_worker(ev.worker)
        elif ev.kind == "pause":
            targets = ([ev.worker] if ev.worker is not None
                       else list(self.active))
            self.paused.update(w for w in targets if w in self.active)
        elif ev.kind == "resume":
            if ev.worker is None:
                self.paused.clear()
            else:
                self.paused.discard(ev.worker)
        elif ev.kind == "coordinator_crash":
            # The one event that targets the control plane itself, not a
            # worker.  Raising here unwinds whatever backend loop applied
            # the event; workers keep draining into their bounded buffers
            # and the serve layer's retry policy resubmits from the latest
            # checkpoint (repro.recover).
            from .types import CoordinatorCrash

            raise CoordinatorCrash(
                f"scenario killed the coordinator at t={t:.6g} "
                f"(wu={self.wu})")
        else:
            raise ValueError(f"unknown scenario event kind {ev.kind!r}")
        if self.tracer is not None:
            self.tracer.scenario_event(t, ev)
        if self.telemetry is not None:
            # (A coordinator_crash raises above and so never lands here —
            # the post-restore "restore" instant marks it instead.)
            self.telemetry.instant("scenario", "coord", t, ev=ev.kind,
                                   worker=ev.worker, src=source)

    # ----------------------------------------------------------------- #
    # Closed-loop autoscaling (repro.autoscale)
    # ----------------------------------------------------------------- #
    def controller_admissible(self, ev) -> bool:
        """Safety rails on controller intents (policies stay unprivileged).

        - never join a worker the *script* holds down (``scenario_down``:
          reclaimed infrastructure), nor one already in the membership,
          nor an id outside the fleet;
        - never preempt or pause away the last dispatchable worker — a
          controller may be wrong, but it may not wedge the run.
        """
        kind, w = ev.kind, ev.worker
        if kind == "join":
            return (w is not None and 0 <= w < self.cfg.n_workers
                    and w not in self.active and w not in self.scenario_down)
        if kind in ("preempt", "pause"):
            live = self.active - self.paused
            return (w in live and len(live) > 1)
        return True  # set_profile / resume are always safe

    def controller_tick(self, t: float, arrivals: Optional[int] = None) -> list:
        """Give the controller a decision opportunity at time ``t``.

        Returns the *applied* actions (possibly []), so backends can do
        their plumbing (launch joined workers, wake parked threads).  Free
        when no controller is configured; between due decision points it
        costs one cadence check.  Uniform across backends: every loop
        calls this at its arrival ticks (plus timed driver points on the
        real backends, where arrivals can stall).  The virtual loops keep
        their own arrival counters (``self.arrivals`` is the real
        backends' shared counter) and pass them in so the ``tick_every``
        cadence means the same thing on every backend.
        """
        ctl = self.cfg.controller
        if ctl is None:
            return []
        if arrivals is None:
            arrivals = self.arrivals
        probe = self.probe
        probe.accumulate(len(self.active - self.paused), t)
        if not probe.due(arrivals, t):
            return []
        sig = probe.sample(self, t, arrivals)
        applied = []
        for ev in (ctl.decide(sig) or []):
            if not self.controller_admissible(ev):
                continue
            ev = _dc_replace(ev, t=t)
            self.apply_scenario_event(ev, t, source="controller")
            self.controller_actions += 1
            ctl.decision_log.append({
                "tick": sig.tick, "t": round(float(t), 9),
                "kind": ev.kind, "worker": ev.worker})
            applied.append(ev)
        return applied

    def round_participants(self) -> List[int]:
        """Sync mode: the workers that take part in the next round."""
        return sorted(self.active - self.paused)

    def round_assignment(self, worker: int) -> np.ndarray:
        """Sync mode: all indices the worker serves this round (its
        assigned blocks concatenated; the single-home-block default
        returns the memoized block object itself)."""
        bs = self.worker_blocks.get(worker) or []
        if len(bs) == 1:
            return self.blocks[bs[0]]
        return np.concatenate([self.blocks[b] for b in bs])

    # ----------------------------------------------------------------- #
    # Index selection
    # ----------------------------------------------------------------- #
    def next_dispatch(self, worker: int) -> Tuple[Optional[int], np.ndarray]:
        """One async dispatch for ``worker``: ``(block_id, indices)``.

        Fixed selection walks the worker's assigned blocks round-robin
        (the static-membership default assignment is ``[worker]``, so this
        returns the memoized ``blocks[worker]`` object unchanged); other
        selections return ``(None, indices)`` exactly as before.
        """
        cfg = self.cfg
        if cfg.selection == "fixed":
            if self._membership_version == 0:
                # Static membership (every scenario-free run): the
                # assignment is the identity — skip the round-robin
                # bookkeeping on the hot dispatch path.
                return worker, self.blocks[worker]
            bs = self.worker_blocks.get(worker) or [worker]
            b = bs[self._rr[worker] % len(bs)]
            self._rr[worker] += 1
            return b, self.blocks[b]
        return None, self._select_indices_dynamic(worker)

    def select_indices(self, worker: int) -> np.ndarray:
        """Per-dispatch selection (async mode: workers launch one at a time)."""
        return self.next_dispatch(worker)[1]

    def _select_indices_dynamic(self, worker: int) -> np.ndarray:
        cfg = self.cfg
        k = cfg.selection_k or max(1, self.problem.n // cfg.n_workers)
        if cfg.selection == "uniform":
            return self.rng.choice(self.problem.n, size=k, replace=False)
        if cfg.selection == "greedy":
            comp = self.problem.component_residual(self.x)
            return np.argpartition(comp, -k)[-k:]
        raise ValueError(f"unknown selection {cfg.selection!r}")

    def select_round_indices(self) -> List[np.ndarray]:
        """Per-round selection (sync mode): one disjoint block per worker.

        Uniform/greedy draw a single pool of ``p*k`` distinct indices and
        partition it, so workers in a barrier round never overlap (the
        pre-refactor engine sampled per worker from the same ``x`` and
        silently overwrote colliding blocks).
        """
        cfg = self.cfg
        p = cfg.n_workers
        if cfg.selection == "fixed":
            return [self.blocks[w] for w in range(p)]
        k = cfg.selection_k or max(1, self.problem.n // p)
        total = min(p * k, self.problem.n)
        if cfg.selection == "uniform":
            pool = self.rng.choice(self.problem.n, size=total, replace=False)
        elif cfg.selection == "greedy":
            comp = self.problem.component_residual(self.x)
            pool = np.argpartition(comp, -total)[-total:]
        else:
            raise ValueError(f"unknown selection {cfg.selection!r}")
        return list(np.array_split(pool, p))

    # ----------------------------------------------------------------- #
    def apply_return(
        self, indices: np.ndarray, values: np.ndarray, profile: FaultProfile,
        staleness: int, worker: Optional[int] = None,
    ) -> bool:
        """Apply one worker return; returns False if dropped.

        ``worker`` (when the backend passes it) feeds the per-worker
        service-fraction accounting; it changes no numerical behaviour.
        """
        cfg = self.cfg
        # Freshness signal for device-resident blocks: True iff this call
        # wrote ``values`` through verbatim (no noise/corruption/damping),
        # i.e. the worker's own copy of the block still mirrors x[ind].
        self.last_apply_verbatim = False
        if profile.max_staleness is not None and staleness > profile.max_staleness:
            self.stale_drops += 1
            return False
        if profile.drop_prob > 0.0 and self.rng.random() < profile.drop_prob:
            self.drops += 1
            return False
        verbatim = True
        if profile.noise_std > 0.0:
            values = values + self.rng.normal(0.0, profile.noise_std, values.shape)
            verbatim = False
        if profile.sample_corrupt(self.rng):
            # Silent-data-corruption channel: the block was corrupted in
            # flight.  Injected coordinator-side (one code path for all
            # four backends), drawn from the coordinator rng so virtual
            # runs stay deterministic; rng untouched when disabled.
            values = profile.corrupt(values, self.rng)
            verbatim = False
        # (full_map returns arrive already restricted to the worker's owned
        # components by the worker_eval wrapper — paper §6 redesign keeps
        # ownership but evaluates globally — so both return modes apply
        # identically here.)
        ind = self._block_slices.get(id(indices), indices)
        if cfg.sdc_guard:
            if not self._sdc_admit(ind, values):
                self.sdc_rejects += 1
                if self.telemetry is not None:
                    self.telemetry.instant("sdc_screen", "coord",
                                           worker=worker)
                if worker is not None and cfg.sdc_strikes > 0:
                    s = self._sdc_strikes.get(worker, 0) + 1
                    self._sdc_strikes[worker] = s
                    if (s >= cfg.sdc_strikes and worker in self.active
                            and len(self.active - self.paused) > 1):
                        # k consecutive strikes: quarantine the repeat
                        # offender through the elastic-membership machinery
                        # (its blocks rebalance to the survivors) — but
                        # never the last dispatchable worker, which would
                        # wedge the run.
                        self.preempt_worker(worker)
                        self.quarantined += 1
                return False
            if worker is not None:
                # Strikes are *consecutive*: an accepted arrival clears the
                # count, so sporadic screen false-positives (a stale-but-
                # legitimate return) never push a healthy worker over the
                # quarantine line in a long run.
                self._sdc_strikes.pop(worker, None)
        if self._pin_watch:
            # Copy-on-write for lazy accel pins: save this block's current
            # values (O(block)) so materialize_pin can undo the write when
            # it reconstructs the begin-time snapshot.  ``ind`` objects are
            # coordinator-owned (memoized slices / the block arrays), so
            # storing them is safe.
            for p in self._pin_watch:
                p._pin_saves.append((ind, np.copy(self.x[ind])))
        if cfg.block_damping is not None:
            a = cfg.block_damping
            self.x[ind] = (1.0 - a) * self.x[ind] + a * values
            verbatim = False
        else:
            self.x[ind] = values
        if not self._trivial_project:
            self.x = _writable(self.problem.project(self.x))
        self.wu += 1
        self.last_apply_verbatim = verbatim
        self._x_version += 1
        if self._fires_inflight > 0:
            self.fire_window_arrivals += 1
        self.staleness_sum += staleness
        self.staleness_n += 1
        if self.telemetry is not None:
            self.telemetry.observe_staleness(staleness)
        if self.probe is not None:  # autoscale signal window; off => free
            self.probe.observe(staleness)
        if worker is not None:
            self.applied_by_worker[worker] = (
                self.applied_by_worker.get(worker, 0) + 1)
        return True

    #: Block-consensus escape: after this many *consecutive* divergence
    #: rejections of the same block, the next finite arrival for it is
    #: admitted regardless of magnitude.  Independent workers keep
    #: producing the same "divergent" value only when the iterate itself
    #: holds the corruption (one slipped through while the baseline was
    #: still warming up) — without the escape the guard would reject the
    #: correction forever and wedge the block.
    _SDC_ESCAPE_REJECTS = 3

    @staticmethod
    def _sdc_block_key(ind):
        """Hashable identity for the screen's per-block reject counter."""
        if isinstance(ind, slice):
            return (ind.start, ind.stop, ind.step)
        a = np.asarray(ind)
        return (int(a[0]), int(a[-1]), int(a.size))

    def _sdc_admit(self, ind, values: np.ndarray) -> bool:
        """SDC screen for one arriving block (``cfg.sdc_guard`` only).

        Two tests: every component finite, and the update norm
        ``||values - x[ind]||`` within ``cfg.sdc_threshold`` times the
        median of the last ``cfg.sdc_window`` *accepted* update norms.
        The baseline warms up before rejecting on divergence (a cold
        median would misfire on the legitimately large early updates),
        and admitted norms feed the window, so the baseline tracks the
        natural decay toward convergence.  A corrupted block is not a
        stale block: stale returns differ from the live iterate by a few
        applied updates, corrupted ones by orders of magnitude.

        The per-block consecutive-reject escape (``_SDC_ESCAPE_REJECTS``)
        keeps the screen self-healing: when a corruption *has* landed in
        the iterate, the stream of rejected "divergent" arrivals is
        actually independent workers agreeing on the correction, and the
        escape lets it through (without feeding its large norm into the
        baseline window).
        """
        if not np.isfinite(values).all():
            return False
        upd = float(np.linalg.norm(values - self.x[ind]))
        base = self._sdc_norms
        key = self._sdc_block_key(ind)
        if len(base) >= max(4, self.cfg.sdc_window // 4):
            med = float(np.median(base))
            if upd > self.cfg.sdc_threshold * max(med, 1e-300):
                n = self._sdc_block_rejects.get(key, 0) + 1
                if n < self._SDC_ESCAPE_REJECTS:
                    self._sdc_block_rejects[key] = n
                    return False
                # Escape: admit the consensus correction; its norm stays
                # out of the baseline (it describes the corruption, not
                # the run's natural update scale).
                self._sdc_block_rejects.pop(key, None)
                return True
        self._sdc_block_rejects.pop(key, None)
        base.append(upd)
        if len(base) > self.cfg.sdc_window:
            del base[0]
        return True

    # ----------------------------------------------------------------- #
    # Durable solves (repro.recover)
    # ----------------------------------------------------------------- #
    def checkpoint_due(self) -> bool:
        ce = self.cfg.checkpoint_every
        return (ce is not None and self.wu > 0 and self.wu % ce == 0
                and self.wu != self._last_ckpt_wu)

    def maybe_checkpoint(self, t: float, loop_state=None) -> bool:
        """Write a SolveCheckpoint if the cadence says one is due.

        Backends call this at arrival boundaries — a consistent point: no
        apply, fire or record is mid-flight.  ``loop_state`` is the
        backend's own resumable loop state (the virtual backend's event
        heap; cadence counters elsewhere), passed as a dict or a zero-arg
        callable evaluated only when a checkpoint is actually due.
        """
        if not self.checkpoint_due():
            return False
        from ...recover.checkpoint import write_checkpoint  # lazy: no cycle

        t_h0 = time.perf_counter()
        write_checkpoint(self, t,
                         loop_state() if callable(loop_state) else loop_state)
        self._last_ckpt_wu = self.wu
        self.checkpoints_written += 1
        if self.telemetry is not None:
            self.telemetry.span(
                "checkpoint", "coord", t, t, wu=self.wu,
                host_dur_s=time.perf_counter() - t_h0)
        return True

    # ----------------------------------------------------------------- #
    # Evaluation pipeline: the accel fire as a begin/feed/commit state
    # machine, and the residual record as begin/commit.  maybe_fire_accel
    # drives it inline (coordinator-evaluated, bit-identical to the
    # pre-split code); backends with cfg.accel_eval == "worker" feed it
    # offloaded evaluations instead.
    # ----------------------------------------------------------------- #
    def eval_item(self, item: EvalItem):
        """Coordinator-side evaluation of one pipeline work item."""
        if item.kind == EvalItem.FULL_MAP:
            return self.problem.full_map(item.x)
        return self.problem.residual_norm(item.x)

    def accel_begin(self, t: float = 0.0,
                    pin: str = "copy") -> Optional[AccelPlan]:
        """Open a fire: pin the iterate, emit the full-map work item.

        Returns None when acceleration is off (or monitor-mode).  The pin
        keeps the plan's evaluations well-defined while arrivals keep
        landing — offloaded staleness stays at the evaluation level.
        ``pin`` selects how:

        * ``"copy"`` — eager O(n) copy (always safe; the historic default);
        * ``"ref"``  — pin the live iterate by reference.  Only for callers
          that drive begin -> feed* -> commit atomically (inline fires): no
          arrival can land mid-plan, the Anderson window copies what it
          keeps, and the commit rebinds rather than mutates, so the copy
          was dead weight.  Counted in ``pin_copies_avoided``.
        * ``"lazy"`` — copy-on-write: pin by reference *and* register the
          plan so :meth:`apply_return` saves each overwritten block's old
          values until :meth:`materialize_pin` reconstructs the begin-time
          snapshot (O(blocks written) instead of O(n) when few arrivals
          land in the begin -> evaluate window).  Requires an identity
          projection (a projection rewrites all of x in place of slices);
          falls back to an eager copy otherwise.
        """
        if self.accel is None or self.cfg.accel_mode == "monitor":
            return None
        if pin == "lazy" and not self._trivial_project:
            pin = "copy"
        if pin == "copy":
            x_pin = self.x.copy()
        else:
            x_pin = self.x
        plan = AccelPlan(x_pin, self.wu, t, self._membership_version)
        if self.telemetry is not None:
            # Recorder clock, not the caller's t: inline fires pass the
            # t=0.0 default, and the recorder's clock matches t anyway on
            # the paths that do pass one.
            plan._tel_t0 = self.telemetry.now()
        if pin == "ref":
            self.pin_copies_avoided += 1
        elif pin == "lazy":
            plan._pin_lazy = True
            self._pin_watch.append(plan)
        self._fires_inflight += 1
        return plan

    def materialize_pin(self, plan: AccelPlan) -> None:
        """Turn a lazy (copy-on-write) pin into a private snapshot.

        Replays the blocks :meth:`apply_return` saved since ``accel_begin``
        onto a copy of the live iterate (newest first), reconstructing the
        begin-time iterate bit-for-bit.  Must run atomically with arrivals
        (under the backend lock / in a single-threaded parent) and before
        the plan's pinned iterate is read outside that atomicity — i.e.
        before the full-map item ships to an evaluator.  Idempotent; no-op
        for eager pins.  Reuses the buffer the last full accel commit
        displaced (``_x_spare``) when shapes allow.
        """
        if not plan._pin_lazy:
            return
        spare = self._x_spare
        if spare is not None and spare.shape == self.x.shape \
                and spare.dtype == self.x.dtype:
            self._x_spare = None
            np.copyto(spare, self.x)
            snap = spare
        else:
            snap = self.x.copy()
        for ind, old in reversed(plan._pin_saves):
            snap[ind] = old
        self.pin_cow_saves += len(plan._pin_saves)
        item = plan._item
        if item is not None and item.x is plan.x_pin:
            item.x = snap
        plan.x_pin = snap
        plan._pin_lazy = False
        plan._pin_saves = []
        try:
            self._pin_watch.remove(plan)
        except ValueError:
            pass

    def accel_feed(self, plan: AccelPlan, value, offloaded: bool = False) -> None:
        """Feed one evaluated item; advances the plan's state machine.

        Stage order (identical float sequence to the pre-split inline
        code): full map -> push/propose (+ candidate projection) -> the
        Eq. 5 safeguard's current-then-candidate residual norms, emitted
        only when there is a candidate to judge.
        """
        cfg, problem = self.cfg, self.problem
        item = plan._item
        plan._item = None
        if offloaded:
            self.offloaded_evals += 1
            if self.tracer is not None and item is not None:
                self.tracer.offload(item.kind)
        elif item is not None and item.kind == EvalItem.FULL_MAP:
            self.coordinator_evals += 1
        if plan.stage == "map":
            g = value
            plan.g = g
            f = problem.accel_residual(plan.x_pin, g)
            self.accel.push(plan.x_pin, g, f)
            cand = self.accel.propose()
            if cand is None:
                plan.verdict = "fallback"  # Eq. 5 fallback: G(x)
                plan.done = True
                return
            plan.cand = _writable(problem.project(cand))
            if cfg.accel.safeguard:
                plan.stage = "cur"
                plan._item = EvalItem(EvalItem.RES_NORM, plan.x_pin)
            else:
                plan.verdict = "accept"
                plan.done = True
            return
        if plan.stage == "cur":
            plan.cur_res = float(value)
            plan.stage = "cand"
            plan._item = EvalItem(EvalItem.RES_NORM, plan.cand)
            return
        # stage "cand": the safeguard has both norms — decide.
        cand_res = float(value)
        if np.isfinite(cand_res) and cand_res < plan.cur_res:
            plan.verdict = "accept"
        else:
            plan.verdict = "fallback"
        plan.done = True

    def accel_commit(self, plan: AccelPlan, t: Optional[float] = None) -> str:
        """Apply the fire's verdict against the live iterate.

        Staleness guard: if more than ``cfg.accel_stale_limit`` worker
        updates were applied since ``accel_begin`` (only possible with
        offloaded evaluations), the fire is *discarded* — neither the
        candidate nor the G(x_pin) fallback may overwrite blocks that are
        fresher than the pinned iterate they were computed from.

        Reassignment windows are handled block-wise: a fire whose
        begin -> commit span crossed a membership change (``plan.mver``
        behind the live version) commits *restricted to the blocks whose
        ownership did not move* in that window — the moved blocks' live
        values may already carry their new server's updates, so only they
        keep their live state (``_block_moved_at`` knows which they are).
        A fire with every block moved degenerates to a discard.
        Returns the applied verdict: "accept" | "fallback" | "discard".
        """
        self._fires_inflight -= 1
        if t is not None:
            self.fire_window_s += max(0.0, t - plan.t_begin)
        stale = self.wu - plan.wu_begin
        moved: set = set()
        if plan.mver != self._membership_version:
            moved = {b for b, mv in self._block_moved_at.items()
                     if mv > plan.mver}
        if stale > self._accel_stale_limit or len(moved) >= len(self.blocks):
            if plan._pin_lazy:
                # Never evaluated: the lazy pin dies without ever paying
                # its copy — a genuinely avoided O(n) pin.
                plan._pin_lazy = False
                plan._pin_saves = []
                try:
                    self._pin_watch.remove(plan)
                except ValueError:
                    pass
                self.pin_copies_avoided += 1
            self.accel_discards += 1
            self.accel.record_reject()
            if self.tracer is not None:
                self.tracer.fire("discard", t)
            if self.telemetry is not None:
                t1 = t if t is not None else self.telemetry.now()
                self.telemetry.fire_span(plan._tel_t0, t1, "discard",
                                         stale=stale, moved=len(moved))
            return "discard"
        # A commit rewrites x wholesale; any *other* lazy pin still watching
        # must snapshot first (its saves only cover block writes, not the
        # rebind below).  The committing plan itself was materialized before
        # its full-map evaluation ran.
        for p in [p for p in self._pin_watch if p is not plan]:
            self.materialize_pin(p)
        if plan.verdict == "accept":
            self.accel.record_accept()
            target = plan.cand
        else:
            self.accel.record_reject()
            target = _writable(self.problem.project(plan.g))
        if moved:
            # Partial commit: write the unmoved blocks from the verdict
            # target, leave the moved blocks' live values in place, then
            # re-project the stitched iterate if projection is non-trivial.
            for b, blk in enumerate(self.blocks):
                if b in moved:
                    continue
                ind = self._block_slices.get(id(blk), blk)
                self.x[ind] = target[ind]
            if not self._trivial_project:
                self.x = _writable(self.problem.project(self.x))
            self.accel_partial_commits += 1
        else:
            # Full rebind: recycle the displaced buffer as the spare the
            # next lazy-pin materialization copies into (double-buffered
            # commit — nothing else can hold this array: lazy pins were
            # materialized above, inline ref pins commit atomically, and
            # eager pins/records hold copies).
            spare = self.x
            self.x = target
            if (self._trivial_project and spare.shape == target.shape
                    and spare.dtype == target.dtype
                    and spare is not target):
                self._x_spare = spare
        self._x_version += 1
        self.commit_version += 1
        if self.tracer is not None:
            self.tracer.fire(plan.verdict, t)
        if self.telemetry is not None:
            t1 = t if t is not None else self.telemetry.now()
            self.telemetry.fire_span(plan._tel_t0, t1, plan.verdict,
                                     stale=stale, moved=len(moved))
        return plan.verdict

    def maybe_fire_accel(self) -> Optional[str]:
        """Coordinator-level Anderson/DIIS (paper §3.4 modes 2 and 3).

        Drives the begin/feed/commit machine with inline evaluations.  Per
        fire this costs one full map, one accel residual, and — only when
        the safeguard actually has a candidate to judge — the two
        residual-norm evaluations Eq. 5 needs.  The degenerate-window and
        safeguard-off paths skip the residual evaluations entirely.
        Returns the applied verdict (None when acceleration is off).

        The pin is by reference: this method drives the whole plan
        atomically (its callers hold the backend lock / are the virtual
        event loop), so no arrival can land between begin and commit and
        the historical O(n) pin copy was dead weight (the Anderson window
        copies what it keeps; commits rebind x rather than mutate it).
        """
        plan = self.accel_begin(pin="ref")
        if plan is None:
            return None
        t0 = time.perf_counter()
        item = plan.next_item()
        while item is not None:
            self.accel_feed(plan, self.eval_item(item))
            item = plan.next_item()
        if self.measure_fire_windows:
            self.fire_window_s += time.perf_counter() - t0
        tel = self.telemetry
        if tel is not None:
            # Close the inline observability gap: offloaded fires count
            # the arrivals applied inside the begin->commit window via
            # apply_return, but an inline fire blocks the loop, so the
            # overlapping work is exactly what is still in flight — count
            # the open dispatches.  Host busy accounting rides along for
            # backends whose metered busy_s is zero (virtual inline).
            tel.host_busy_s += time.perf_counter() - t0
            self.fire_window_arrivals += tel.open_tasks
        return self.accel_commit(plan)

    # ----------------------------------------------------------------- #
    # Shared real-backend loop machinery (thread / process / ray).  The
    # virtual backend keeps its own event-loop copies to preserve the
    # bit-identical golden runs.
    # ----------------------------------------------------------------- #
    def plan_round(
        self, alive: Set[int], round_idx: Sequence[np.ndarray]
    ) -> List[Tuple[int, FaultProfile, np.ndarray, float, bool]]:
        """Sample per-worker (delay, crash) plans for one BSP round.

        Draws come from the coordinator rng in worker order, so the fault
        sequence is reproducible given a seed even though real-backend
        round *timing* is not.
        """
        plans = []
        for w in sorted(alive):
            prof = self.fault_for(w)
            delay = prof.sample_delay(self.rng)
            crashed = prof.sample_crash(self.rng)
            plans.append((w, prof, round_idx[w], delay, crashed))
        return plans

    def note_sync_crash(self, prof: FaultProfile, w: int,
                        alive: Set[int]) -> None:
        """Account one planned BSP crash (the barrier stall is already paid
        worker-side): lost in-flight result, permanent exit or rejoin."""
        self.crashes += 1
        if prof.restart_after is None:
            alive.discard(w)
        else:
            self.restarts += 1

    def sync_round_tick(self, rounds: int, elapsed) -> Tuple[float, Optional[str]]:
        """Real-backend round epilogue: barrier overhead, accel cadence,
        residual record and stop checks.  Returns ``(t, verdict)`` with
        verdict ``None`` (continue), ``"converged"``/``"diverged"``
        (assemble the result) or ``"budget"`` (max_wall exceeded)."""
        cfg = self.cfg
        if cfg.sync_overhead > 0.0:
            time.sleep(cfg.sync_overhead)
        if self.accel is not None and rounds % cfg.fire_every == 0:
            self.maybe_fire_accel()
        t = elapsed()
        res = self.record(t)
        if not np.isfinite(res) or res > 1e60:
            return t, "diverged"
        if self.converged():
            return t, "converged"
        if cfg.max_wall is not None and t > cfg.max_wall:
            return t, "budget"
        return t, None

    def arrival_tick(self, t: float) -> bool:
        """Per-arrival bookkeeping shared by every real async backend
        (thread, process, ray): arrival/record-cadence counters plus every
        stop condition.  Returns True when the run should stop.  Callers
        with concurrent arrivals (the thread backend) must hold their
        coordinator lock.  (The virtual backend keeps its own event-loop
        copy to preserve bit-identical golden runs.)"""
        self.arrivals += 1
        self.since_record += 1
        if self.telemetry is not None:
            self.telemetry.maybe_sample_busy(t, self.busy_s)
        stop = self.arrivals >= self.max_arrivals
        if self.since_record >= self.record_every:
            res = self.record(t)
            self.since_record = 0
            if not np.isfinite(res) or res > 1e60:
                stop = True
            elif self.converged():
                stop = True
        if self.wu >= self.cfg.max_updates:
            stop = True
        if self.cfg.max_wall is not None and t > self.cfg.max_wall:
            stop = True
        return stop

    def arrival_tick_offload(self, t: float) -> Tuple[bool, bool]:
        """Worker-eval variant of :meth:`arrival_tick`.

        Same counters and inline stop checks, but a due residual record is
        *reported* (second return value) instead of evaluated on the spot —
        the backend turns it into a :meth:`record_begin` plan and feeds the
        offloaded value back through :meth:`record_commit`, where the
        convergence/divergence verdict is taken.
        """
        self.arrivals += 1
        self.since_record += 1
        if self.telemetry is not None:
            self.telemetry.maybe_sample_busy(t, self.busy_s)
        stop = self.arrivals >= self.max_arrivals
        record_due = False
        if self.since_record >= self.record_every:
            record_due = True
            self.since_record = 0
        if self.wu >= self.cfg.max_updates:
            stop = True
        if self.cfg.max_wall is not None and t > self.cfg.max_wall:
            stop = True
        return stop, record_due

    def record(self, t: float) -> float:
        tel = self.telemetry
        t_h0 = time.perf_counter() if tel is not None else 0.0
        self.res_norm = self.problem.residual_norm(self.x)
        self._res_version = self._x_version
        self.history.append((t, self.wu, self.res_norm))
        if self.tracer is not None:
            self.tracer.record(t, self.res_norm)
        if tel is not None:
            tel.host_busy_s += time.perf_counter() - t_h0
            tel.span("record", "coord", t, t, res=self.res_norm, wu=self.wu)
            tel.series_point("residual", t, self.res_norm)
            tel.maybe_sample_busy(t, self.busy_s)
        return self.res_norm

    def record_begin(self, t: float) -> RecordPlan:
        """Open an offloaded residual record at the current iterate."""
        return RecordPlan(self.x.copy(), self.wu, t, self._x_version)

    def record_commit(self, plan: RecordPlan, value,
                      offloaded: bool = False) -> float:
        """Feed the evaluated residual norm back; returns it (the backend
        applies the same finite/divergence/convergence verdict the inline
        ``record`` callers do)."""
        if offloaded:
            self.offloaded_evals += 1
        plan.done = True
        plan._item = None
        self.res_norm = float(value)
        self._res_version = plan.x_version
        self.history.append((plan.t, plan.wu, self.res_norm))
        if self.tracer is not None:
            self.tracer.record(plan.t, self.res_norm)
        if self.telemetry is not None:
            tel = self.telemetry
            tel.span("record", "coord", plan.t, plan.t,
                     res=self.res_norm, wu=plan.wu, offloaded=offloaded)
            tel.series_point("residual", plan.t, self.res_norm)
        return self.res_norm

    def converged(self) -> bool:
        if self.cfg.converge_on == "error":
            err = self.problem.error_norm(self.x)
            return err is not None and err < self.cfg.tol
        return self.res_norm < self.cfg.tol

    def result(self, t: float, rounds: int, converged: bool) -> RunResult:
        mean_stale = self.staleness_sum / max(self.staleness_n, 1)
        acc = self.accel
        if self.probe is not None:  # close the worker-seconds meter at t
            self.probe.accumulate(len(self.active - self.paused), t)
        # Reuse the recorded residual when x has not moved since record()
        # evaluated it (the common case: every run path records right
        # before assembling the result) — recomputing it at the same x
        # would return the identical float for one more full map.
        if self._res_version == self._x_version:
            res = self.res_norm
        else:
            res = self.problem.residual_norm(self.x)
        busy_frac = min(1.0, self.busy_s / t) if t > 0 else 0.0
        tel = self.telemetry
        tel_capture = tel_summary = None
        if tel is not None:
            if self.busy_s == 0.0:
                # Inline virtual runs never meter busy_s (coordinator work
                # is free in virtual time); the recorder's host-clock
                # fraction closes the inline observability gap.
                busy_frac = tel.host_busy_frac()
            tel.finalize(t, self.busy_s)
            tel_capture = tel.to_capture()
            tel_summary = tel_capture.summary
        return RunResult(
            x=self.x,
            converged=converged,
            worker_updates=self.wu,
            wall_time=t,
            residual_norm=res,
            history=self.history,
            rounds=rounds,
            drops=self.drops,
            stale_drops=self.stale_drops,
            accel_fires=acc.n_fire if acc else 0,
            accel_accepts=acc.n_accept if acc else 0,
            accel_rejects=acc.n_reject if acc else 0,
            coordinator_evals=self.coordinator_evals,
            mean_staleness=mean_stale,
            error_norm=self.problem.error_norm(self.x),
            crashes=self.crashes,
            restarts=self.restarts,
            offloaded_evals=self.offloaded_evals,
            accel_discards=self.accel_discards,
            accel_partial_commits=self.accel_partial_commits,
            coordinator_busy_frac=busy_frac,
            fire_window_s=self.fire_window_s,
            fire_window_arrivals=self.fire_window_arrivals,
            preemptions=self.preemptions,
            joins=self.joins,
            reassigned_blocks=self.reassigned_blocks,
            preempt_discards=self.preempt_discards,
            service_fractions={
                w: cnt / max(self.wu, 1)
                for w, cnt in sorted(self.applied_by_worker.items())},
            worker_seconds=(self.probe.worker_seconds
                            if self.probe is not None else 0.0),
            controller_actions=self.controller_actions,
            sdc_rejects=self.sdc_rejects,
            quarantined=self.quarantined,
            checkpoints_written=self.checkpoints_written,
            resumed_from=self.resumed_from,
            pin_copies_avoided=self.pin_copies_avoided,
            pin_cow_saves=self.pin_cow_saves,
            device_dispatches=self.device_dispatches,
            device_refreshes=self.device_refreshes,
            trace=(self.tracer.to_trace() if self.tracer is not None
                   else None),
            telemetry=tel_capture,
            telemetry_summary=tel_summary,
        )
