"""Generic LRU registry for persistent worker pools, with refcounted leases.

Both multi-interpreter backends keep expensive worker fleets alive across
``run()`` calls — the process backend's spawned interpreters (a JAX import
plus jit warm-up each) and the Ray backend's actors (the same cost inside
Ray worker processes).  The keying, health-check, LRU-eviction and
shutdown logic is identical, so it lives here once:

- a pool is keyed on :func:`payload_key` — the sha256 of the pickled
  problem payload (an identity-keyed cache would go silently stale if a
  caller mutated a problem in place) plus ``(n_workers, return_mode)``;
- :meth:`PoolRegistry.acquire` returns a refcounted :class:`PoolLease` on
  the live pool for a key (creating it via the caller's factory, replacing
  one whose ``healthy()`` went false).  While any lease is outstanding the
  pool is pinned: LRU overflow skips it and :meth:`PoolRegistry.dispose`
  defers the actual ``close()`` until the last lease is released, so a
  concurrent request can never have its serving pool torn down underneath
  it.  Pools beyond ``max_pools`` with no leases are closed oldest-first
  (the capacity bound is therefore soft while requests are in flight and
  re-established as they drain);
- each registry entry also carries a ``run_lock`` — leases on the same key
  share it, so concurrent sessions of one payload family serialize their
  *exclusive* use of the fleet (setup_run/dispatch/drain) while still
  sharing the single warm pool with zero respawns;
- :meth:`PoolRegistry.get` is the legacy unleased accessor (same reuse and
  eviction semantics, no pinning);
- :meth:`PoolRegistry.shutdown` closes everything (backends register it
  with ``atexit``), including pools with outstanding leases — at interpreter
  exit the worker fleets must die regardless.

Pool objects only need ``close()`` and ``healthy()``; everything else
(queues, shared memory, actors) is the backend's business.  This module
has no optional dependencies, so the registry/gating logic is unit-testable
even where ``ray`` is not installed.
"""

from __future__ import annotations

import hashlib
import pickle
import threading
from collections import OrderedDict
from typing import Callable, Iterator, List, Tuple

__all__ = ["PoolRegistry", "PoolLease", "payload_key"]


def payload_key(payload, cfg) -> Tuple[str, int, str]:
    """Registry key for a (problem payload, RunConfig) pair.

    The payload is hashed fresh on every ``run()``; the pickle+sha256 of a
    realistic payload (sub-MB) costs ~1-2 ms, noise next to even a warm
    run, and guarantees a mutated problem never reuses a pool built from
    the old operator.
    """
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    return (hashlib.sha256(blob).hexdigest(), cfg.n_workers, cfg.return_mode)


class _Entry:
    """One registry slot: the pool plus its lease/eviction bookkeeping."""

    __slots__ = ("pool", "leases", "retired", "run_lock")

    def __init__(self, pool):
        self.pool = pool
        self.leases = 0  # outstanding PoolLease handles
        self.retired = False  # evicted/disposed; close when leases drain
        self.run_lock = threading.Lock()  # exclusive fleet use per session


class PoolLease:
    """Refcounted handle on a registry pool (also a context manager).

    Holding a lease pins the pool: the registry will not close it — not for
    LRU overflow, not for :meth:`PoolRegistry.dispose` — until the lease is
    released.  ``run_lock`` serializes exclusive use of the fleet among
    same-key leases.  The lease holds its entry directly, so a concurrent
    dispose-plus-recreate under the same key can never mis-route a release
    to the replacement pool.
    """

    __slots__ = ("_registry", "key", "_entry", "_released")

    def __init__(self, registry: "PoolRegistry", key, entry: _Entry):
        self._registry = registry
        self.key = key
        self._entry = entry
        self._released = False

    @property
    def pool(self):
        return self._entry.pool

    @property
    def run_lock(self) -> threading.Lock:
        return self._entry.run_lock

    def release(self) -> None:
        """Drop the refcount (idempotent); may close a retired/excess pool."""
        if self._released:
            return
        self._released = True
        self._registry._release(self.key, self._entry)

    def __enter__(self) -> "PoolLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class PoolRegistry:
    """LRU-bounded key -> pool mapping with health-checked, leased reuse."""

    def __init__(self, max_pools: int):
        self.max_pools = max(1, int(max_pools))
        self._lock = threading.RLock()
        self._entries: "OrderedDict" = OrderedDict()
        # Per-key creation locks: concurrent cold boots of *different*
        # families proceed in parallel; of the same family, one factory
        # call runs and the others reuse its pool.
        self._creating: dict = {}
        # Telemetry: how many times each key's pool was built from scratch
        # (respawns = created_count - 1; 0 respawns = pure warm reuse).
        self._created: dict = {}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def items(self) -> Iterator:
        with self._lock:
            return iter([(k, e.pool) for k, e in self._entries.items()])

    def lease_count(self, key) -> int:
        """Outstanding leases on ``key`` (0 for unknown keys)."""
        with self._lock:
            e = self._entries.get(key)
            return 0 if e is None else e.leases

    def created_count(self, key) -> int:
        """Times a pool was built for ``key`` (0 for never-seen keys)."""
        with self._lock:
            return self._created.get(key, 0)

    # ------------------------------------------------------------------ #
    def acquire(self, key, factory: Callable) -> PoolLease:
        """Lease the live pool for ``key``, creating it via ``factory``.

        A cached pool whose ``healthy()`` is false is retired (closed once
        its leases drain) and replaced.  The leased pool is marked
        most-recently-used; unleased pools beyond ``max_pools`` are closed
        oldest-first.
        """
        entry, stale = self._obtain(key, factory, leased=True)
        for e in stale:
            e.pool.close()
        return PoolLease(self, key, entry)

    def get(self, key, factory: Callable):
        """Legacy unleased accessor: same reuse/eviction, no pinning."""
        entry, stale = self._obtain(key, factory, leased=False)
        for e in stale:
            e.pool.close()
        return entry.pool

    def _obtain(self, key, factory, leased: bool) -> Tuple[_Entry, List[_Entry]]:
        """Return (live entry for key, entries to close outside the lock).

        With ``leased``, the refcount is bumped under the registry lock so
        the entry can never be evicted between lookup and lease creation.
        """
        stale: List[_Entry] = []
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    if entry.pool.healthy():
                        if leased:
                            entry.leases += 1
                        self._entries.move_to_end(key)
                        stale.extend(self._evict_excess(protect=key))
                        return entry, stale
                    # Dead pool: retire it (close now if nothing holds it).
                    del self._entries[key]
                    entry.retired = True
                    if entry.leases == 0:
                        stale.append(entry)
                ck = self._creating.setdefault(key, threading.Lock())
            with ck:
                with self._lock:
                    if key in self._entries:
                        continue  # built by a concurrent acquire; re-validate
                # Factory runs outside the registry lock (pool boot is slow)
                # but inside the per-key lock (one boot per family).
                pool = factory()
                with self._lock:
                    self._created[key] = self._created.get(key, 0) + 1
                    entry = _Entry(pool)
                    if leased:
                        entry.leases += 1
                    self._entries[key] = entry
                    self._creating.pop(key, None)
                    stale.extend(self._evict_excess(protect=key))
                return entry, stale

    def _evict_excess(self, protect=None) -> List[_Entry]:
        """Pop unleased LRU entries beyond capacity (caller closes them).

        Leased pools are skipped — the capacity bound is soft while
        requests are in flight — and re-checked on release.  Caller holds
        the registry lock.
        """
        out: List[_Entry] = []
        excess = len(self._entries) - self.max_pools
        if excess <= 0:
            return out
        for k in list(self._entries):
            if excess <= 0:
                break
            e = self._entries[k]
            if k == protect or e.leases > 0:
                continue
            del self._entries[k]
            e.retired = True
            out.append(e)
            excess -= 1
        return out

    def _release(self, key, entry: _Entry) -> None:
        close_now: List[_Entry] = []
        with self._lock:
            entry.leases = max(0, entry.leases - 1)
            if entry.leases == 0 and entry.retired:
                close_now.append(entry)
            close_now.extend(self._evict_excess())
        for e in close_now:
            e.pool.close()

    # ------------------------------------------------------------------ #
    def dispose(self, key) -> None:
        """Forget one pool (no-op for unknown keys).

        The pool closes immediately when unleased; with leases outstanding
        it is retired — unreachable for new acquires, closed when the last
        lease releases — so disposing a sick pool never tears it out from
        under a concurrent request.
        """
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is not None:
                entry.retired = True
                if entry.leases > 0:
                    entry = None
        if entry is not None:
            entry.pool.close()

    def shutdown(self) -> None:
        """Close every pool (oldest first), leased or not (atexit path)."""
        with self._lock:
            entries = list(self._entries.values())
            self._entries.clear()
        for e in entries:
            e.retired = True
            e.pool.close()
