"""Generic LRU registry for persistent worker pools.

Both multi-interpreter backends keep expensive worker fleets alive across
``run()`` calls — the process backend's spawned interpreters (a JAX import
plus jit warm-up each) and the Ray backend's actors (the same cost inside
Ray worker processes).  The keying, health-check, LRU-eviction and
shutdown logic is identical, so it lives here once:

- a pool is keyed on :func:`payload_key` — the sha256 of the pickled
  problem payload (an identity-keyed cache would go silently stale if a
  caller mutated a problem in place) plus ``(n_workers, return_mode)``;
- :meth:`PoolRegistry.get` returns the live pool for a key, replacing one
  whose ``healthy()`` went false, creating one via the caller's factory
  otherwise, and closing least-recently-used pools beyond ``max_pools``;
- :meth:`PoolRegistry.shutdown` closes everything (backends register it
  with ``atexit``).

Pool objects only need ``close()`` and ``healthy()``; everything else
(queues, shared memory, actors) is the backend's business.  This module
has no optional dependencies, so the registry/gating logic is unit-testable
even where ``ray`` is not installed.
"""

from __future__ import annotations

import hashlib
import pickle
from collections import OrderedDict
from typing import Callable, Dict, Iterator, Tuple

__all__ = ["PoolRegistry", "payload_key"]


def payload_key(payload, cfg) -> Tuple[str, int, str]:
    """Registry key for a (problem payload, RunConfig) pair.

    The payload is hashed fresh on every ``run()``; the pickle+sha256 of a
    realistic payload (sub-MB) costs ~1-2 ms, noise next to even a warm
    run, and guarantees a mutated problem never reuses a pool built from
    the old operator.
    """
    blob = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    return (hashlib.sha256(blob).hexdigest(), cfg.n_workers, cfg.return_mode)


class PoolRegistry:
    """LRU-bounded key -> pool mapping with health-checked reuse."""

    def __init__(self, max_pools: int):
        self.max_pools = max(1, int(max_pools))
        self._pools: "OrderedDict" = OrderedDict()

    def __len__(self) -> int:
        return len(self._pools)

    def items(self) -> Iterator:
        return iter(list(self._pools.items()))

    def get(self, key, factory: Callable):
        """Return the live pool for ``key``, creating it via ``factory``.

        A cached pool whose ``healthy()`` is false is closed and replaced;
        the returned pool is marked most-recently-used and older pools
        beyond ``max_pools`` are closed.
        """
        pool = self._pools.get(key)
        if pool is not None and not pool.healthy():
            self._pools.pop(key, None)
            pool.close()
            pool = None
        if pool is None:
            pool = factory()
            self._pools[key] = pool
        self._pools.move_to_end(key)  # LRU
        while len(self._pools) > self.max_pools:
            _, old = self._pools.popitem(last=False)
            old.close()
        return pool

    def dispose(self, key) -> None:
        """Close and forget one pool (no-op for unknown keys)."""
        pool = self._pools.pop(key, None)
        if pool is not None:
            pool.close()

    def shutdown(self) -> None:
        """Close every pool (oldest first)."""
        while self._pools:
            _, pool = self._pools.popitem(last=False)
            pool.close()
