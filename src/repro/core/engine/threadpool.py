"""Real-concurrency thread-pool executor.

Workers are OS threads evaluating ``block_update`` concurrently; straggler
delays are injected with real ``time.sleep`` and wall time is measured with
``time.perf_counter``.  This reproduces the paper's sync-vs-async speedups
on actual hardware (Hannah & Yin, arXiv:1708.05136; Assran et al.,
arXiv:2006.13838: asynchronous gains only manifest under genuine concurrency
with real stragglers) — the virtual-time simulator predicts them, this
backend measures them.

Coordinator state is protected by a single lock; worker evaluations (jitted
JAX / numpy kernels, which release the GIL) and injected sleeps run outside
it, so workers genuinely overlap.  ``cfg.compute_time`` is ignored — compute
cost is whatever the hardware takes.  Runs are NOT bit-reproducible across
invocations (arrival order is real scheduling), but with ``n_workers=1`` the
trajectory matches the synchronous one and converges to the same fixed
point, which is the parity contract tested in ``tests/test_executors.py``.

EvalService (``cfg.accel_eval == "worker"``, async mode): accel fires and
residual records run through the coordinator's begin/feed/commit pipeline
on a dedicated eval thread instead of inline under the lock — the full-map
and safeguard evaluations (which release the GIL) overlap with arrivals,
so the coordinator's lock-held work stays O(block).  A simulated eval-
service fault (``FaultProfile.eval_crash_prob``) makes the pipeline fall
back to coordinator-side evaluation for that item.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor as _Pool
from typing import Optional

import numpy as np

from ..fixedpoint import FixedPointProblem
from .base import Executor, register_executor
from .coordinator import (
    LAZY_PIN_MIN_N,
    Coordinator,
    warm_problem,
    worker_eval,
)
from .types import (
    CoordinatorCrash,
    FaultProfile,
    RunConfig,
    RunResult,
    _fault_for,
)

__all__ = ["ThreadPoolExecutor"]

# With an autoscale controller and the script drained, an empty/paused
# membership is not necessarily final — the controller may join a spare or
# resume a pause at a later timed tick.  This is how long the loops wait
# for it to do so before declaring the run wedged and stopping; without a
# controller they stop immediately (the pre-existing behaviour).
_CTL_STALL_S = 2.0


@register_executor
class ThreadPoolExecutor(Executor):
    """Concurrent workers in a thread pool; wall time is real seconds."""

    name = "thread"

    def _execute(self, session) -> RunResult:
        problem, cfg = session.problem, session.cfg
        coord = Coordinator(problem, cfg)
        coord.measure_fire_windows = True  # real clock: time inline fires
        # Warm every jit specialization the run will hit (per-block shapes,
        # selection-sized blocks, the accel/residual full-map path) before
        # the clock starts, so compile time doesn't skew wall-clock.  The
        # coordinator's memoized partition is passed through so exactly the
        # dispatched block objects get warmed.
        warm_problem(problem, cfg, blocks=coord.blocks)
        if cfg.accel is not None:
            problem.full_map(coord.x)
        problem.residual_norm(coord.x)
        if cfg.capture_trace and cfg.mode == "async":
            from ...chaos.trace import TraceRecorder

            coord.tracer = TraceRecorder(cfg, self.name, problem)
        if cfg.mode == "sync":
            if cfg.scenario is not None or cfg.controller is not None:
                return self._run_sync_chaos(problem, cfg, coord)
            return self._run_sync(problem, cfg, coord)
        if cfg.mode == "async":
            if cfg.scenario is not None or cfg.controller is not None:
                # The chaos loop hosts both eval placements: with
                # accel_eval="worker" it opens fire/record plans and runs
                # them on the eval thread, and commits are restricted to
                # blocks whose ownership did not move (coordinator guard).
                # Controller-driven runs land here too (with an empty
                # ScenarioClock when there is no script): membership can
                # change mid-run, which only this loop's parking handles.
                return self._run_async_chaos(problem, cfg, coord)
            if cfg.accel_eval == "worker":
                return self._run_async_offload(problem, cfg, coord)
            if cfg.capture_trace:
                return self._run_async_chaos(problem, cfg, coord)
            return self._run_async(problem, cfg, coord)
        raise ValueError(f"unknown mode {cfg.mode!r}")

    # ----------------------------------------------------------------- #
    @staticmethod
    def _sync_task(
        problem: FixedPointProblem, cfg: RunConfig, x_snap: np.ndarray,
        idx: np.ndarray, delay: float, crashed: bool,
        profile: FaultProfile,
    ) -> Optional[np.ndarray]:
        vals = worker_eval(problem, cfg, x_snap, idx)
        if delay > 0.0:
            time.sleep(delay)
        if crashed:
            # BSP: the barrier stalls until the worker restarts; its
            # in-flight result is lost either way.
            if profile.restart_after is not None:
                time.sleep(profile.restart_after)
            return None
        return vals

    def _run_sync(
        self, problem: FixedPointProblem, cfg: RunConfig, coord: Coordinator
    ) -> RunResult:
        t0 = time.perf_counter()
        rounds = 0
        alive = set(range(cfg.n_workers))
        tel = coord.telemetry
        if tel is not None:
            tel.install_clock(lambda: time.perf_counter() - t0)
        coord.record(0.0)
        with _Pool(max_workers=cfg.n_workers) as pool:
            while (coord.wu < cfg.max_updates and alive
                   and coord.arrivals < coord.max_arrivals):
                rounds += 1
                x_snap = coord.x.copy()
                rs = time.perf_counter() - t0
                plans = coord.plan_round(alive, coord.select_round_indices())
                futs = [
                    pool.submit(self._sync_task, problem, cfg, x_snap, idx,
                                delay, crashed, prof)
                    for _, prof, idx, delay, crashed in plans
                ]
                for (w, prof, idx, _, crashed), fut in zip(plans, futs):
                    vals = fut.result()
                    coord.arrivals += 1
                    if tel is not None:
                        tel.task_open(w, rs)
                        tel.task_close(
                            w, disp="crash" if crashed else "applied")
                    if crashed:
                        coord.note_sync_crash(prof, w, alive)
                        continue
                    coord.apply_return(idx, vals, prof, staleness=0)
                t, verdict = coord.sync_round_tick(
                    rounds, lambda: time.perf_counter() - t0)
                if verdict in ("diverged", "converged"):
                    return coord.result(t, rounds, verdict == "converged")
                if verdict == "budget":
                    break
        t = time.perf_counter() - t0
        return coord.result(t, rounds, coord.converged())

    # ----------------------------------------------------------------- #
    def _run_async(
        self, problem: FixedPointProblem, cfg: RunConfig, coord: Coordinator
    ) -> RunResult:
        lock = threading.Lock()
        stop = threading.Event()
        state = {"since_fire": 0}  # arrival/record counters live on coord
        # Per-worker generators for delay/crash draws keep the coordinator
        # rng (drop/noise/selection) behind the lock and everything else out.
        seeds = np.random.SeedSequence(cfg.seed).spawn(cfg.n_workers)
        worker_rngs = [np.random.default_rng(s) for s in seeds]
        if cfg.resume_from is not None:
            # Reconstruct a checkpointed solve: the coordinator (and with
            # it the iterate, rng, Anderson window, counters) restores from
            # the snapshot; the wall clock continues from the checkpoint's
            # time so wall_time stays cumulative across the kill.  Worker
            # rngs re-derive from the seed — deterministic single-worker
            # fault-free runs continue bit-identically; faulty multi-worker
            # runs continue correctly (arrival order is real scheduling
            # either way).
            from ...recover.checkpoint import (
                resolve_checkpoint, restore_coordinator)

            ckpt = resolve_checkpoint(cfg.resume_from)
            restore_coordinator(coord, ckpt)
            loop = ckpt.loop
            if loop.get("kind") != "thread_async":
                raise ValueError(
                    f"checkpoint loop state is {loop.get('kind')!r}, not "
                    "resumable on the thread backend's async loop")
            state["since_fire"] = int(loop.get("since_fire", 0))
            t0 = time.perf_counter() - ckpt.t
        else:
            t0 = time.perf_counter()
            coord.record(0.0)

        def elapsed() -> float:
            return time.perf_counter() - t0

        tel = coord.telemetry
        if tel is not None:
            tel.install_clock(elapsed)

        def _loop_state():
            return ({"kind": "thread_async",
                     "since_fire": state["since_fire"]}, {})

        # Device-resident data plane (cfg.device_plane): when the run
        # shape qualifies, each worker keeps its block resident as a
        # device array and per dispatch ships only the halo/dependency
        # slices its update reads — the O(n) snapshot copy and full-x
        # transfer disappear from the hot loop.  Resolution is structural
        # (see engine.device_plane); problems opt in per block.
        from .device_plane import resolve_device_plane

        dmode = resolve_device_plane(problem, cfg, self.name)
        dplans = {}
        if dmode is not None:
            for dw in range(cfg.n_workers):
                dp = problem.device_block_plan(coord.blocks[dw], dmode)
                if dp is not None:
                    dplans[dw] = dp
            if dplans:
                # Warm the fused-kernel specializations before the clock
                # starts (mirrors warm_problem for the host path).
                zx = np.zeros(problem.n)
                for dw, dp in dplans.items():
                    dp.refresh(zx[coord.blocks[dw]])
                    dp.step(*[zx[s] for s in dp.needs])

        def worker_loop(w: int) -> None:
            prof = _fault_for(cfg, w)
            rng = worker_rngs[w]
            dp = dplans.get(w)
            dev_fresh = False  # resident block mirrors x[block]?
            dev_cver = -1  # commit_version at the last freshness grant
            while not stop.is_set():
                with lock, coord.busy():
                    if stop.is_set():
                        return
                    if not coord.dispatchable(w):
                        # Quarantined by the k-strikes SDC policy, or out
                        # of a resumed membership: this thread is done
                        # (static fault-free runs never take this exit).
                        return
                    launch_wu = coord.wu
                    idx = coord.select_indices(w)
                    if tel is not None:
                        tel.task_open(w, elapsed())
                    if dp is not None:
                        # Fresh resident block: ship only the halo slices
                        # (O(needs)); stale: re-ship the block (O(block)).
                        # Never the full iterate.
                        blk_vals = None
                        if not (dev_fresh
                                and coord.commit_version == dev_cver):
                            blk_vals = np.copy(coord.x[idx])
                        need_vals = [np.copy(coord.x[s]) for s in dp.needs]
                    else:
                        x_snap = coord.x.copy()
                if dp is not None:
                    if blk_vals is not None:
                        dp.refresh(blk_vals)
                    vals, dev_norm = dp.step(*need_vals)
                else:
                    vals = worker_eval(problem, cfg, x_snap, idx)
                if cfg.async_overhead > 0.0:
                    time.sleep(cfg.async_overhead)
                delay = prof.sample_delay(rng)
                if delay > 0.0:
                    time.sleep(delay)
                if prof.sample_crash(rng):
                    # A crash is still an arrival: it counts toward the
                    # record cadence and the stop checks must run, or an
                    # all-crashing worker set would spin forever.  The
                    # resident block advanced past the lost return, so it
                    # no longer mirrors x.
                    dev_fresh = False
                    with lock:
                        coord.crashes += 1
                        if tel is not None:
                            tel.task_close(w, disp="crash")
                        if coord.arrival_tick(elapsed()):
                            stop.set()
                    if prof.restart_after is None or stop.is_set():
                        return  # permanent crash (or run over): thread exits
                    time.sleep(prof.restart_after)
                    with lock:
                        if stop.is_set():
                            return  # run ended mid-downtime: never rejoined
                        coord.restarts += 1
                        if tel is not None:
                            tel.instant("restart", f"w{w}")
                    continue
                with lock, coord.busy():
                    if stop.is_set():
                        return
                    staleness = coord.wu - launch_wu
                    applied = coord.apply_return(
                        idx, vals, prof, staleness=staleness, worker=w
                    )
                    if tel is not None:
                        # Before any inline fire below: its open-task count
                        # must cover only the *other* workers in flight.
                        tel.task_close(
                            w, disp="applied" if applied else "filtered",
                            staleness=staleness)
                    if dp is not None:
                        coord.device_dispatches += 1
                        if blk_vals is not None:
                            coord.device_refreshes += 1
                        coord.device_local_norms[w] = dev_norm
                        # Fresh iff our values landed verbatim; any commit
                        # after this point (own fire below or another
                        # worker's) bumps commit_version and invalidates.
                        dev_fresh = applied and coord.last_apply_verbatim
                        dev_cver = coord.commit_version
                    if applied:
                        state["since_fire"] += 1
                        if (coord.accel is not None
                                and state["since_fire"] >= cfg.fire_every):
                            coord.maybe_fire_accel()
                            state["since_fire"] = 0
                    if coord.arrival_tick(elapsed()):
                        stop.set()
                    coord.maybe_checkpoint(elapsed(), _loop_state)

        threads = [
            threading.Thread(target=worker_loop, args=(w,), daemon=True,
                             name=f"fp-worker-{w}")
            for w in range(cfg.n_workers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        t = elapsed()
        with lock:
            coord.record(t)
            return coord.result(t, coord.wu, coord.converged())

    # ----------------------------------------------------------------- #
    def _run_sync_chaos(
        self, problem: FixedPointProblem, cfg: RunConfig, coord: Coordinator
    ) -> RunResult:
        """BSP loop under a chaos scenario: events apply at round
        boundaries (the barrier is the BSP granularity); preempted workers
        leave the round set with their blocks served by survivors, paused
        workers idle, and when nobody can take a round the loop sleeps to
        the next scripted event."""
        from ...chaos.scenario import ScenarioClock

        clock = ScenarioClock(cfg.scenario)
        t0 = time.perf_counter()
        rounds = 0
        idle_since = 0.0  # last time a round actually ran (stall window)
        alive = set(range(cfg.n_workers))

        def elapsed() -> float:
            return time.perf_counter() - t0

        tel = coord.telemetry
        if tel is not None:
            tel.install_clock(elapsed)
        coord.record(0.0)

        with _Pool(max_workers=cfg.n_workers) as pool:
            while (coord.wu < cfg.max_updates and alive
                   and coord.arrivals < coord.max_arrivals):
                now = elapsed()
                for ev in clock.due(now):
                    coord.apply_scenario_event(ev, now)
                # Controller decisions land at round boundaries (the BSP
                # granularity); the round set below is re-derived from the
                # membership, so actions need no plumbing.
                coord.controller_tick(now)
                parts = [w for w in coord.round_participants() if w in alive]
                if not parts:
                    nt = clock.next_time()
                    if nt is None:
                        if cfg.controller is None:
                            break  # membership can never recover
                        # A controller may still rebuild the membership
                        # (join a spare, resume a pause) — give it a
                        # bounded stall window of timed ticks.
                        if now - idle_since > _CTL_STALL_S:
                            break
                        if (cfg.max_wall is not None
                                and elapsed() > cfg.max_wall):
                            break
                        time.sleep(0.01)
                        continue
                    time.sleep(max(0.0, nt - elapsed()))
                    continue
                idle_since = elapsed()
                rounds += 1
                x_snap = coord.x.copy()
                rs = elapsed()
                round_idx = {w: coord.round_assignment(w) for w in parts}
                plans = coord.plan_round(set(parts), round_idx)
                futs = [
                    pool.submit(self._sync_task, problem, cfg, x_snap, idx,
                                delay, crashed, prof)
                    for _, prof, idx, delay, crashed in plans
                ]
                for (w, prof, idx, _, crashed), fut in zip(plans, futs):
                    vals = fut.result()
                    coord.arrivals += 1
                    if tel is not None:
                        tel.task_open(w, rs, gen=coord.preempt_gen[w])
                        tel.task_close(
                            w, disp="crash" if crashed else "applied",
                            gen=coord.preempt_gen[w])
                    if crashed:
                        coord.note_sync_crash(prof, w, alive)
                        continue
                    coord.apply_return(idx, vals, prof, staleness=0, worker=w)
                t, verdict = coord.sync_round_tick(rounds, elapsed)
                if verdict in ("diverged", "converged"):
                    return coord.result(t, rounds, verdict == "converged")
                if verdict == "budget":
                    break
        t = elapsed()
        return coord.result(t, rounds, coord.converged())

    # ----------------------------------------------------------------- #
    def _run_async_chaos(
        self, problem: FixedPointProblem, cfg: RunConfig, coord: Coordinator
    ) -> RunResult:
        """Async loop with chaos scenarios and/or trace capture.

        A dedicated chaos-driver thread wakes at each scripted event time
        and applies it under the coordinator lock; worker threads park on
        a condition while they are preempted or paused (and exit once no
        future join can revive them).  A result computed across its
        worker's preemption is discarded at the apply point
        (``preempt_gen`` recognizes the stale incarnation), mirroring the
        virtual backend's semantics on wall clock.

        With ``cfg.accel_eval == "worker"`` the EvalService composes with
        chaos: due fires/records only *open* plans under the lock and
        evaluate on a dedicated eval thread (as in
        :meth:`_run_async_offload`).  A fire whose begin→commit window
        spans a membership change commits restricted to the blocks that
        did not move (the coordinator's ``AccelPlan.mver`` guard).
        """
        from ...chaos.scenario import ScenarioClock

        offload = cfg.accel_eval == "worker"
        lock = threading.Lock()
        cond = threading.Condition(lock)
        stop = threading.Event()
        state = {"since_fire": 0, "fire_plan": None, "rec_plan": None,
                 "crash": None}
        clock = ScenarioClock(cfg.scenario)
        seeds = np.random.SeedSequence(cfg.seed).spawn(cfg.n_workers + 1)
        worker_rngs = [np.random.default_rng(s) for s in seeds[:-1]]
        eval_rng = np.random.default_rng(seeds[-1])
        eval_pool = (_Pool(max_workers=1, thread_name_prefix="fp-eval")
                     if offload else None)
        t0 = time.perf_counter()
        with cond:
            for ev in clock.due(0.0):
                coord.apply_scenario_event(ev, 0.0)
            # Initial controller decision (tick 0) shapes the membership
            # before worker threads take their first dispatch; no plumbing
            # needed — threads park/dispatch off coord.dispatchable.
            coord.controller_tick(0.0)
        coord.record(0.0)

        def elapsed() -> float:
            return time.perf_counter() - t0

        tel = coord.telemetry
        if tel is not None:
            tel.install_clock(elapsed)

        def eval_one(item, prof: FaultProfile):
            e0 = elapsed()
            if (prof.eval_crash_prob > 0.0
                    and eval_rng.random() < prof.eval_crash_prob):
                val, offloaded = coord.eval_item(item), False
            else:
                val, offloaded = coord.eval_item(item), True
            if tel is not None:
                tel.span("eval", "eval", e0, elapsed(), offload=offloaded)
            return val, offloaded

        def run_fire(plan, prof: FaultProfile) -> None:
            if plan._pin_lazy:
                # Lazy pin: snapshot atomically with arrivals, right before
                # the full-map item leaves the lock for the eval thread.
                # (_pin_lazy is set before the plan is submitted and only
                # ever cleared, so the unlocked check is race-free; eager
                # pins skip the lock round-trip entirely.)
                with cond, coord.busy():
                    coord.materialize_pin(plan)
            item = plan.next_item()
            while item is not None:
                val, offloaded = eval_one(item, prof)
                with cond, coord.busy():
                    coord.accel_feed(plan, val, offloaded=offloaded)
                item = plan.next_item()
            with cond, coord.busy():
                if not stop.is_set():
                    coord.accel_commit(plan, t=elapsed())
                state["fire_plan"] = None

        def run_record(plan, prof: FaultProfile) -> None:
            val, offloaded = eval_one(plan.next_item(), prof)
            with cond, coord.busy():
                state["rec_plan"] = None
                if stop.is_set():
                    return
                res = coord.record_commit(plan, val, offloaded=offloaded)
                if not np.isfinite(res) or res > 1e60:
                    stop.set()
                    cond.notify_all()
                elif coord.converged():
                    # Confirm at the live iterate (same contract as the
                    # scenario-free offload loop).
                    res = coord.record(elapsed())
                    if (not np.isfinite(res) or res > 1e60
                            or coord.converged()):
                        stop.set()
                        cond.notify_all()

        def arrival_tick_either(prof: FaultProfile) -> bool:
            """Record-cadence/stop tick; caller holds the lock."""
            if not offload:
                return coord.arrival_tick(elapsed())
            tick_stop, record_due = coord.arrival_tick_offload(elapsed())
            if record_due and state["rec_plan"] is None:
                state["rec_plan"] = coord.record_begin(elapsed())
                eval_pool.submit(run_record, state["rec_plan"], prof)
            return tick_stop

        def chaos_driver() -> None:
            # With a controller the driver doubles as its timed ticker:
            # arrivals normally drive decisions, but when every member is
            # down arrivals stall, and only these timed ticks let the
            # controller rebuild the membership (bounded by _CTL_STALL_S
            # once the script is drained and nothing is live).
            ctl = cfg.controller is not None
            idle_since: Optional[float] = None
            while not stop.is_set():
                nt = clock.next_time()
                if nt is None and not ctl:
                    with cond:
                        if not (coord.active - coord.paused):
                            # Nobody can ever take work again: the script
                            # ended with the membership empty/paused.
                            stop.set()
                            cond.notify_all()
                    return
                if nt is None and ctl:
                    if stop.wait(0.02):
                        return
                    with cond:
                        now = elapsed()
                        acted = bool(coord.controller_tick(now))
                        if acted:
                            cond.notify_all()
                        if (coord.active - coord.paused) or acted:
                            idle_since = None
                        elif idle_since is None:
                            idle_since = now
                        elif now - idle_since > _CTL_STALL_S:
                            stop.set()
                            cond.notify_all()
                            return
                        if cfg.max_wall is not None and now > cfg.max_wall:
                            stop.set()
                            cond.notify_all()
                            return
                    continue
                while True:
                    wait = nt - elapsed()
                    if wait <= 0:
                        break
                    if stop.wait(min(wait, 0.02) if ctl else wait):
                        return
                    if ctl:
                        with cond:
                            if coord.controller_tick(elapsed()):
                                cond.notify_all()
                with cond:
                    now = elapsed()
                    try:
                        for ev in clock.due(now):
                            coord.apply_scenario_event(ev, now)
                    except CoordinatorCrash as e:
                        # The control plane just died.  Stop every worker
                        # (they drain their in-flight results and exit —
                        # nothing commits past this point) and hand the
                        # crash to the main thread to re-raise.
                        state["crash"] = e
                        stop.set()
                        cond.notify_all()
                        return
                    if ctl:
                        coord.controller_tick(now)
                    cond.notify_all()

        def worker_loop(w: int) -> None:
            rng = worker_rngs[w]
            while not stop.is_set():
                with cond:
                    while not stop.is_set() and not coord.dispatchable(w):
                        if clock.exhausted and cfg.controller is None:
                            # join/resume only ever come from the script:
                            # an undispatchable worker with the script
                            # drained can never work again — exit so the
                            # run can finish even if every other worker
                            # is already gone.  (A controller can revive
                            # this worker at any later tick, so keep
                            # parking; the driver's stall window bounds
                            # the wait when nothing can ever recover.)
                            return
                        cond.wait(0.05)
                    if stop.is_set():
                        return
                    gen = coord.preempt_gen[w]
                    x_snap = coord.x.copy()
                    launch_wu = coord.wu
                    bid, idx = coord.next_dispatch(w)
                    prof = coord.fault_for(w)
                    if coord.tracer is not None:
                        coord.tracer.dispatch(elapsed(), w, bid, gen)
                    if tel is not None:
                        tel.task_open(w, elapsed(), gen=gen, block=bid)
                vals = worker_eval(problem, cfg, x_snap, idx)
                if cfg.async_overhead > 0.0:
                    time.sleep(cfg.async_overhead)
                delay = prof.sample_delay(rng)
                if delay > 0.0:
                    time.sleep(delay)
                if prof.sample_crash(rng):
                    with cond, coord.busy():
                        if stop.is_set():
                            return
                        if gen != coord.preempt_gen[w]:
                            coord.preempt_discards += 1
                            if coord.tracer is not None:
                                coord.tracer.arrival(elapsed(), w,
                                                     "preempt_discard",
                                                     gen=gen)
                            if tel is not None:
                                tel.task_close(w, disp="preempt_discard",
                                               gen=gen)
                            continue  # park at loop top until join
                        coord.crashes += 1
                        if coord.tracer is not None:
                            coord.tracer.arrival(elapsed(), w, "crash",
                                                 gen=gen)
                        if tel is not None:
                            tel.task_close(w, disp="crash", gen=gen)
                        if arrival_tick_either(prof):
                            stop.set()
                            cond.notify_all()
                        elif coord.controller_tick(elapsed()):
                            cond.notify_all()  # wake workers a join freed
                    if prof.restart_after is None or stop.is_set():
                        return  # permanent crash (or run over): thread exits
                    time.sleep(prof.restart_after)
                    with cond:
                        if stop.is_set():
                            return
                        if gen == coord.preempt_gen[w]:
                            # Downtime ended inside the same incarnation:
                            # the restart rejoins (downtime-end convention).
                            coord.restarts += 1
                            if coord.tracer is not None:
                                coord.tracer.restart(elapsed(), w)
                            if tel is not None:
                                tel.instant(
                                    "restart",
                                    f"w{w}" if gen == 0 else f"w{w}#r{gen}")
                    continue
                with cond, coord.busy():
                    if stop.is_set():
                        return
                    if gen != coord.preempt_gen[w]:
                        coord.preempt_discards += 1
                        if coord.tracer is not None:
                            coord.tracer.arrival(elapsed(), w,
                                                 "preempt_discard", gen=gen)
                        if tel is not None:
                            tel.task_close(w, disp="preempt_discard",
                                           gen=gen)
                        continue
                    staleness = coord.wu - launch_wu
                    applied = coord.apply_return(
                        idx, vals, prof, staleness=staleness, worker=w
                    )
                    if coord.tracer is not None:
                        coord.tracer.arrival(
                            elapsed(), w,
                            "applied" if applied else "filtered", staleness,
                            gen=gen)
                    if tel is not None:
                        tel.task_close(
                            w, disp="applied" if applied else "filtered",
                            staleness=staleness, gen=gen)
                    if applied:
                        state["since_fire"] += 1
                        if (coord.accel is not None
                                and state["since_fire"] >= cfg.fire_every):
                            if offload:
                                state["since_fire"] = 0
                                if state["fire_plan"] is None:
                                    plan = coord.accel_begin(
                                        elapsed(),
                                        pin=("lazy" if coord.x.size
                                             >= LAZY_PIN_MIN_N else "copy"))
                                    if plan is not None:
                                        state["fire_plan"] = plan
                                        eval_pool.submit(run_fire, plan, prof)
                            else:
                                coord.maybe_fire_accel()
                                state["since_fire"] = 0
                    if arrival_tick_either(prof):
                        stop.set()
                        cond.notify_all()
                    elif coord.controller_tick(elapsed()):
                        # The controller acted at this arrival: a preempt
                        # of this very worker parks it at the loop top (its
                        # gen is stale now); a join frees a parked worker.
                        cond.notify_all()
                    coord.maybe_checkpoint(
                        elapsed(),
                        lambda: ({"kind": "thread_async",
                                  "since_fire": state["since_fire"]}, {}))

        threads = [
            threading.Thread(target=worker_loop, args=(w,), daemon=True,
                             name=f"fp-worker-{w}")
            for w in range(cfg.n_workers)
        ]
        driver = threading.Thread(target=chaos_driver, daemon=True,
                                  name="fp-chaos-driver")
        for th in threads:
            th.start()
        driver.start()
        for th in threads:
            th.join()
        stop.set()  # in-flight plans must not commit after the final record
        driver.join(timeout=5.0)
        if eval_pool is not None:
            eval_pool.shutdown(wait=True)
        if state["crash"] is not None:
            # coordinator_crash scenario event: the run has no result — the
            # serve layer's retry policy resubmits from the latest
            # checkpoint (repro.recover).
            raise state["crash"]
        t = elapsed()
        with lock:
            coord.record(t)
            return coord.result(t, coord.wu, coord.converged())

    # ----------------------------------------------------------------- #
    def _run_async_offload(
        self, problem: FixedPointProblem, cfg: RunConfig, coord: Coordinator
    ) -> RunResult:
        """Async loop with the EvalService on a dedicated eval thread.

        Worker threads behave exactly as in :meth:`_run_async`, but a due
        fire only *opens* an :class:`AccelPlan` under the lock (a lazy
        copy-on-write pin — O(1) at begin, materialized on the eval thread
        right before its first evaluation) — its full-map/safeguard
        evaluations run on the eval thread, which feeds results back and
        commits with the staleness guard.
        Residual records take the same path.  At most one fire and one
        record are in flight; further due fires/records are coalesced.
        """
        lock = threading.Lock()
        stop = threading.Event()
        state = {"since_fire": 0, "fire_plan": None, "rec_plan": None}
        # Per-worker generators for delay/crash draws (as in _run_async);
        # one extra stream drives the eval service's simulated faults.
        seeds = np.random.SeedSequence(cfg.seed).spawn(cfg.n_workers + 1)
        worker_rngs = [np.random.default_rng(s) for s in seeds[:-1]]
        eval_rng = np.random.default_rng(seeds[-1])
        eval_pool = _Pool(max_workers=1, thread_name_prefix="fp-eval")
        t0 = time.perf_counter()
        coord.record(0.0)

        def elapsed() -> float:
            return time.perf_counter() - t0

        tel = coord.telemetry
        if tel is not None:
            tel.install_clock(elapsed)

        def eval_one(item, prof: FaultProfile):
            """Evaluate one pipeline item, simulating eval-service loss.

            Returns ``(value, offloaded)``: a crashed evaluation falls
            back to coordinator-side evaluation of the same item.
            """
            e0 = elapsed()
            if (prof.eval_crash_prob > 0.0
                    and eval_rng.random() < prof.eval_crash_prob):
                val, offloaded = coord.eval_item(item), False
            else:
                val, offloaded = coord.eval_item(item), True
            if tel is not None:
                tel.span("eval", "eval", e0, elapsed(), offload=offloaded)
            return val, offloaded

        def run_fire(plan, prof: FaultProfile) -> None:
            if plan._pin_lazy:
                # Lazy pin: snapshot atomically with arrivals, right before
                # the full-map item leaves the lock for the eval thread.
                # (_pin_lazy is set before the plan is submitted and only
                # ever cleared, so the unlocked check is race-free; eager
                # pins skip the lock round-trip entirely.)
                with lock, coord.busy():
                    coord.materialize_pin(plan)
            item = plan.next_item()
            while item is not None:
                val, offloaded = eval_one(item, prof)
                with lock, coord.busy():
                    coord.accel_feed(plan, val, offloaded=offloaded)
                item = plan.next_item()
            with lock, coord.busy():
                if not stop.is_set():
                    coord.accel_commit(plan, t=elapsed())
                state["fire_plan"] = None

        def run_record(plan, prof: FaultProfile) -> None:
            val, offloaded = eval_one(plan.next_item(), prof)
            with lock, coord.busy():
                state["rec_plan"] = None
                if stop.is_set():
                    return
                res = coord.record_commit(plan, val, offloaded=offloaded)
                if not np.isfinite(res) or res > 1e60:
                    stop.set()
                elif coord.converged():
                    # The offloaded record judged the *pinned* iterate;
                    # arrivals may have landed since.  Confirm at the live
                    # iterate so the final verdict matches the state the
                    # run actually returns (same contract as inline mode).
                    res = coord.record(elapsed())
                    if (not np.isfinite(res) or res > 1e60
                            or coord.converged()):
                        stop.set()

        def worker_loop(w: int) -> None:
            prof = _fault_for(cfg, w)
            rng = worker_rngs[w]
            while not stop.is_set():
                with lock, coord.busy():
                    if stop.is_set():
                        return
                    if not coord.dispatchable(w):
                        return  # quarantined by the k-strikes SDC policy
                    x_snap = coord.x.copy()
                    launch_wu = coord.wu
                    bid, idx = coord.next_dispatch(w)
                    if coord.tracer is not None:
                        coord.tracer.dispatch(elapsed(), w, bid)
                    if tel is not None:
                        tel.task_open(w, elapsed(), block=bid)
                vals = worker_eval(problem, cfg, x_snap, idx)
                if cfg.async_overhead > 0.0:
                    time.sleep(cfg.async_overhead)
                delay = prof.sample_delay(rng)
                if delay > 0.0:
                    time.sleep(delay)
                if prof.sample_crash(rng):
                    with lock, coord.busy():
                        coord.crashes += 1
                        if coord.tracer is not None:
                            coord.tracer.arrival(elapsed(), w, "crash")
                        if tel is not None:
                            tel.task_close(w, disp="crash")
                        tick_stop, record_due = coord.arrival_tick_offload(
                            elapsed())
                        if record_due and state["rec_plan"] is None:
                            state["rec_plan"] = coord.record_begin(elapsed())
                            eval_pool.submit(run_record, state["rec_plan"],
                                             prof)
                        if tick_stop:
                            stop.set()
                    if prof.restart_after is None or stop.is_set():
                        return
                    time.sleep(prof.restart_after)
                    with lock:
                        if stop.is_set():
                            return  # run ended mid-downtime: never rejoined
                        coord.restarts += 1
                        if coord.tracer is not None:
                            coord.tracer.restart(elapsed(), w)
                        if tel is not None:
                            tel.instant("restart", f"w{w}")
                    continue
                with lock, coord.busy():
                    if stop.is_set():
                        return
                    staleness = coord.wu - launch_wu
                    applied = coord.apply_return(
                        idx, vals, prof, staleness=staleness, worker=w
                    )
                    if coord.tracer is not None:
                        coord.tracer.arrival(
                            elapsed(), w,
                            "applied" if applied else "filtered", staleness)
                    if tel is not None:
                        tel.task_close(
                            w, disp="applied" if applied else "filtered",
                            staleness=staleness)
                    if applied:
                        state["since_fire"] += 1
                        if (coord.accel is not None
                                and state["since_fire"] >= cfg.fire_every):
                            state["since_fire"] = 0
                            if state["fire_plan"] is None:
                                plan = coord.accel_begin(
                                    elapsed(),
                                    pin=("lazy" if coord.x.size
                                         >= LAZY_PIN_MIN_N else "copy"))
                                if plan is not None:
                                    state["fire_plan"] = plan
                                    eval_pool.submit(run_fire, plan, prof)
                    tick_stop, record_due = coord.arrival_tick_offload(
                        elapsed())
                    if record_due and state["rec_plan"] is None:
                        state["rec_plan"] = coord.record_begin(elapsed())
                        eval_pool.submit(run_record, state["rec_plan"], prof)
                    if tick_stop:
                        stop.set()

        threads = [
            threading.Thread(target=worker_loop, args=(w,), daemon=True,
                             name=f"fp-worker-{w}")
            for w in range(cfg.n_workers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        stop.set()  # in-flight plans must not commit after the final record
        eval_pool.shutdown(wait=True)
        t = elapsed()
        with lock:
            coord.record(t)
            return coord.result(t, coord.wu, coord.converged())
