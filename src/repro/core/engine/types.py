"""Shared engine datatypes: fault profiles, run configuration, run results.

These are backend-agnostic: the same :class:`RunConfig` drives the
deterministic virtual-time simulator and the real-concurrency thread,
process, and Ray backends (``cfg.executor`` selects which — see
:mod:`repro.core.engine.base`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from ..anderson import AndersonConfig

__all__ = ["FaultProfile", "RunConfig", "RunResult", "CoordinatorCrash"]


class CoordinatorCrash(RuntimeError):
    """The control plane died mid-solve.

    Raised out of a backend's coordinator loop when a chaos scenario's
    ``coordinator_crash`` event fires: the session fails (workers keep
    draining into their bounded buffers and are torn down with the loop),
    and any checkpoints written so far stay on disk.  The serve layer's
    crash-retry policy (``ServiceConfig.crash_retries``) catches exactly
    this type and resubmits the solve from the latest checkpoint.
    """


@dataclass
class FaultProfile:
    """Per-worker fault injection (paper §4).

    ``delay``/``noise``/``drop``/``max_staleness`` are the paper's four
    fault channels.  ``crash_prob``/``restart_after`` extend them with
    worker churn: with probability ``crash_prob`` per update the worker
    crashes — its in-flight result is lost — and it rejoins after
    ``restart_after`` seconds (``None`` means it never comes back).  Every
    backend honours the same semantics; the virtual-time backend charges
    virtual seconds for delays and downtime, the thread/process/ray
    backends sleep through real ones.  ``RunResult.restarts`` counts a
    restart when the downtime *ends* on every backend, so a run that stops
    while a worker is still down never reports a restart that did not
    rejoin.
    """

    delay_mean: float = 0.0  # seconds added per update (virtual or real)
    delay_std: float = 0.0
    noise_std: float = 0.0  # additive N(0, std) on returned components
    drop_prob: float = 0.0  # probability a returned update is lost
    max_staleness: Optional[int] = None  # in worker-updates; older => dropped
    crash_prob: float = 0.0  # probability per update the worker crashes
    restart_after: Optional[float] = None  # seconds down; None => permanent
    # Evaluation-service fault channel (``RunConfig.accel_eval="worker"``):
    # probability that one offloaded full-map / residual-norm evaluation is
    # lost in flight.  The coordinator falls back to evaluating that item
    # itself, so a lossy eval service degrades throughput, never correctness.
    eval_crash_prob: float = 0.0
    # Silent-data-corruption channel (Coleman & Sosonkina-style faults that
    # *corrupt* data instead of delaying it): with probability
    # ``corrupt_prob`` per returned update, the worker's value block is
    # corrupted in flight.  Unlike delay/staleness this is not a bounded
    # perturbation — a single corrupted block poisons the iterate and every
    # subsequent Anderson window unless the coordinator-side guard
    # (``RunConfig.sdc_guard``) rejects it.  Modes: ``"bitflip"`` flips one
    # random bit of one float64 element, ``"nan"`` overwrites one element
    # with NaN, ``"scale"`` multiplies one element by 1e8.
    corrupt_prob: float = 0.0
    corrupt_mode: str = "bitflip"  # "bitflip" | "nan" | "scale"

    def sample_delay(self, rng: np.random.Generator) -> float:
        if self.delay_mean == 0.0 and self.delay_std == 0.0:
            return 0.0
        return max(0.0, rng.normal(self.delay_mean, self.delay_std))

    def sample_crash(self, rng: np.random.Generator) -> bool:
        """Draw a crash event; consumes randomness only when enabled."""
        return self.crash_prob > 0.0 and rng.random() < self.crash_prob

    def sample_corrupt(self, rng: np.random.Generator) -> bool:
        """Draw an SDC event; consumes randomness only when enabled."""
        return self.corrupt_prob > 0.0 and rng.random() < self.corrupt_prob

    def corrupt(self, values: np.ndarray,
                rng: np.random.Generator) -> np.ndarray:
        """Return a corrupted *copy* of ``values`` (one element hit)."""
        v = np.array(values, dtype=np.float64)
        i = int(rng.integers(v.size))
        if self.corrupt_mode == "nan":
            v[i] = np.nan
        elif self.corrupt_mode == "scale":
            v[i] *= 1e8
        elif self.corrupt_mode == "bitflip":
            bit = np.uint64(int(rng.integers(64)))
            u = v.view(np.uint64)
            u[i] ^= np.uint64(1) << bit
        else:
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}")
        return v


@dataclass
class RunConfig:
    """One (a)synchronous run of a fixed-point problem."""

    n_workers: int = 4
    mode: str = "async"  # "sync" | "async"
    # --- execution backend (see repro.core.engine.base) ------------------- #
    executor: str = "virtual"  # "virtual" | "thread" | "process" | "ray"
    # --- acceleration -------------------------------------------------- #
    accel: Optional[AndersonConfig] = None
    accel_mode: str = "coordinator"  # "monitor" | "coordinator" | "periodic"
    fire_every: int = 1  # E: fire each E worker returns (async) / rounds (sync)
    # --- damping -------------------------------------------------------- #
    block_damping: Optional[float] = None  # damped application of block updates
    # --- selection (paper §5.2 / Fig. 6) --------------------------------- #
    selection: str = "fixed"  # "fixed" | "uniform" | "greedy"
    selection_k: Optional[int] = None  # block size for uniform/greedy
    # --- worker return mode (paper §6 future work) ----------------------- #
    return_mode: str = "block"  # "block" | "full_map"
    # --- evaluation pipeline placement (paper §6 redesign) ---------------- #
    # Where the accel/record full-map and safeguard-residual evaluations run
    # in async mode.  "coordinator" (default) evaluates them inline — the
    # pre-existing behaviour, bit-identical on the virtual backend — while
    # "worker" offloads them through the backend's EvalService so fires and
    # residual records overlap with arrivals (the evaluations then see a
    # pinned, slightly stale iterate: evaluation-level staleness only).
    # Sync mode always evaluates coordinator-side (workers idle at the
    # barrier anyway, so there is nothing to overlap with).
    accel_eval: str = "coordinator"  # "coordinator" | "worker"
    # Staleness guard for offloaded fires: if more than this many worker
    # updates were applied between accel_begin and accel_commit, the fire is
    # discarded instead of overwriting the fresher blocks (this is what
    # keeps offload an evaluation-level perturbation rather than
    # iterate-level corruption).  None => 4 * n_workers.
    accel_stale_limit: Optional[int] = None
    # Virtual backend only: seconds of virtual time one offloaded (or, with
    # accel_eval="coordinator", one coordinator-side) full-map /
    # residual-norm evaluation costs.  Setting it (or accel_eval="worker")
    # opts the async virtual loop into the evaluation-cost event model that
    # predicts the offload speedup; None with coordinator eval keeps the
    # golden-tested event loop byte-for-byte.
    eval_time: Optional[float] = None
    # --- termination ------------------------------------------------------ #
    tol: float = 1e-6
    max_updates: int = 200_000
    # Liveness guard: total worker returns (applied + dropped + stale +
    # crashed) before the run stops.  max_updates only counts *applied*
    # updates, so a run whose returns never apply (drop_prob=1, all-crash
    # churn) would otherwise spin forever.  None => 10 * max_updates.
    max_arrivals: Optional[int] = None
    max_wall: Optional[float] = None  # seconds (virtual or real)
    record_every: Optional[int] = None  # residual check cadence (default p)
    # --- determinism / timing --------------------------------------------- #
    seed: int = 0
    compute_time: Optional[float] = None  # virtual s/update; None => measure
    sync_overhead: float = 0.0  # per-round barrier cost (BSP coordination)
    async_overhead: float = 0.0  # per-dispatch cost in async mode
    faults: Union[None, FaultProfile, Dict[int, FaultProfile]] = None
    converge_on: str = "residual"  # "residual" | "error"
    # --- chaos scenarios (repro.chaos) ------------------------------------ #
    # A FaultScenario of timestamped events (set_profile / preempt / join /
    # pause / resume and delay-trace segments) interpreted against virtual
    # time on the virtual backend and wall time on thread/process/ray, so
    # one script means the same thing everywhere.  Preempted workers'
    # blocks are reassigned to the least-loaded survivors (elastic
    # membership) and handed back on join.  Requires selection="fixed";
    # composes with accel_eval="worker" on the real backends (a fire whose
    # begin->commit window crossed a membership change commits only to the
    # blocks whose ownership did not move), while the virtual chaos loop
    # still evaluates coordinator-side.  None keeps every default loop
    # untouched.
    scenario: Optional[object] = None  # repro.chaos.FaultScenario
    # --- closed-loop autoscaling (repro.autoscale) ------------------------ #
    # A Controller policy observing ControlSignals (arrival rate, staleness
    # histogram, accel discard rates, queue depth) at arrival ticks and
    # emitting the same join/preempt/pause/set_profile events scenarios
    # script — actuated through apply_scenario_event on every backend, so
    # one policy means the same thing everywhere and composes with a
    # scripted scenario (script = weather, controller = pilot; the
    # coordinator's safety rails stop a policy from resurrecting workers
    # the script reclaimed or wedging the membership).  Requires
    # selection="fixed".  None keeps every default loop untouched and
    # bit-identical.
    controller: Optional[object] = None  # repro.autoscale.Controller
    # Record the run's event trace (dispatches, arrivals + dispositions,
    # crashes, fires, records, offloads) into RunResult.trace for
    # deterministic postmortem replay (repro.chaos.replay_trace).  Async
    # mode with selection="fixed" only.
    capture_trace: bool = False
    # --- durable solves (repro.recover) ----------------------------------- #
    # Write a SolveCheckpoint (JSON + npz under checkpoint_dir) every this
    # many applied worker updates: a consistent coordinator snapshot taken
    # at an arrival boundary (iterate, rng, Anderson window, membership,
    # accounting, and — on the virtual backend — the event heap, so a
    # resumed virtual run is bit-identical to the uninterrupted one).
    # None disables checkpointing and leaves every default loop untouched.
    checkpoint_every: Optional[int] = None
    checkpoint_dir: Optional[str] = None  # required when checkpoint_every set
    # Resume handle: a repro.recover.SolveCheckpoint (or a path to one).
    # The backend restores the coordinator from it before entering its loop
    # instead of starting from problem.initial_state(); use
    # repro.recover.resume_fixed_point rather than setting this directly.
    resume_from: Optional[object] = None
    # --- SDC quarantine (coordinator-side guard) --------------------------- #
    # Screen every arriving block for NaN/Inf and for update norms that
    # diverge from a windowed baseline of recently accepted update norms;
    # rejected arrivals count RunResult.sdc_rejects (never applied), and a
    # worker collecting sdc_strikes rejections is quarantined — preempted
    # through the elastic-membership machinery, its blocks rebalanced to
    # the survivors (RunResult.quarantined).  Off by default: the guard
    # consumes no randomness and default paths stay bit-identical.
    sdc_guard: bool = False
    sdc_window: int = 32  # baseline window (accepted update norms)
    sdc_threshold: float = 25.0  # reject when norm > threshold * median
    sdc_strikes: int = 3  # rejections before quarantine (0 => never)
    # --- device-resident data plane (kernels + real backends) -------------- #
    # Keep each worker's block resident as a JAX array across the dispatch
    # loop, shipping only halo/dependency slices per dispatch and running
    # the fused block-update(+local-residual) kernels instead of
    # re-materializing the full iterate host-side.  Modes:
    #   "off"        — host numpy path everywhere (pre-existing behaviour)
    #   "auto"       — (default) flips the jnp device path on for real
    #                  backends once n >= 2**20 and the run shape qualifies
    #                  (async, fixed selection, block returns, identity
    #                  projection, no scenario/controller/trace); otherwise
    #                  identical to "off"
    #   "jnp"/"on"   — force the fused jitted-jnp device step
    #   "pallas"     — force the fused Pallas kernels (TPU lowering)
    #   "interpret"  — force the Pallas kernels in interpret mode (CPU
    #                  validation of the exact kernel bodies; slow)
    # The virtual backend always ignores this knob — fixed-seed virtual
    # runs stay bit-identical to the goldens whatever it is set to.
    device_plane: str = "auto"
    # Unified telemetry plane (repro.telemetry): None (default, zero-cost
    # — no recorder is ever constructed), True, or a TelemetryConfig.
    # When set, the coordinator owns a TelemetryRecorder collecting typed
    # spans + metric series; the full capture lands on RunResult.telemetry
    # and a compact digest on RunResult.telemetry_summary.  The recorder
    # consumes no rng and touches no iterate floats, so enabling it never
    # changes a trajectory on any backend.
    telemetry: Optional[object] = None


@dataclass
class RunResult:
    x: np.ndarray
    converged: bool
    worker_updates: int
    wall_time: float
    residual_norm: float
    history: List[Tuple[float, int, float]]  # (t, WU, residual norm)
    rounds: int = 0  # sync: barrier rounds; async: applied updates
    drops: int = 0
    stale_drops: int = 0
    accel_fires: int = 0
    accel_accepts: int = 0
    accel_rejects: int = 0
    coordinator_evals: int = 0  # full-map evaluations done by the coordinator
    mean_staleness: float = 0.0
    error_norm: Optional[float] = None
    crashes: int = 0  # worker crash events (in-flight update lost)
    restarts: int = 0  # crashed workers that rejoined
    # --- evaluation pipeline (accel_eval="worker") ------------------------ #
    offloaded_evals: int = 0  # eval items served worker-side
    accel_discards: int = 0  # fires dropped by the commit staleness guard
    # Fires whose begin->commit window crossed a membership change and
    # committed restricted to the blocks whose ownership did not move
    # (chaos scenarios composed with accel_eval="worker").
    accel_partial_commits: int = 0
    # Fraction of the run the coordinator spent doing its own work (apply,
    # inline fires/records, commit bookkeeping) — measured on the real
    # backends, modeled on the virtual eval-cost loop, 0.0 otherwise.
    coordinator_busy_frac: float = 0.0
    # Accumulated fire-window time (begin -> commit, backend clock) and the
    # worker updates applied inside those windows: arrivals/sec-while-firing
    # is fire_window_arrivals / fire_window_s (0 when fires are evaluated
    # inline — the coordinator blocks arrivals for the whole window).
    fire_window_s: float = 0.0
    fire_window_arrivals: int = 0
    # --- elastic membership (repro.chaos scenarios) ----------------------- #
    preemptions: int = 0  # workers removed from the membership by a scenario
    joins: int = 0  # workers that (re)joined the membership
    reassigned_blocks: int = 0  # block moves across preempt/join events
    preempt_discards: int = 0  # in-flight results discarded by a preemption
    # Fraction of applied worker updates each worker served (sums to ~1.0
    # over the workers that applied anything; static membership gives each
    # worker ~1/p).
    service_fractions: Dict[int, float] = field(default_factory=dict)
    # --- closed-loop autoscaling (repro.autoscale) ------------------------- #
    # Integral of |active - paused| over the run (the capacity actually
    # provisioned) — the cost model's first factor.  Metered only when a
    # controller is configured (the probe owns the meter); 0.0 otherwise.
    worker_seconds: float = 0.0
    controller_actions: int = 0  # applied controller decisions
    # --- durable solves (repro.recover) ------------------------------------ #
    sdc_rejects: int = 0  # corrupted arrivals rejected by the SDC guard
    quarantined: int = 0  # workers quarantined by the k-strikes policy
    checkpoints_written: int = 0  # SolveCheckpoints written this run
    resumed_from: Optional[str] = None  # checkpoint tag this run resumed from
    # --- device-resident data plane --------------------------------------- #
    # Inline (atomic) accel fires pin the iterate by reference instead of
    # copying all of x — one avoided O(n) copy per inline fire.
    pin_copies_avoided: int = 0
    # Offloaded fires pin lazily (copy-on-write): each counts one O(block)
    # save performed while the pin was unmaterialized, instead of the
    # eager O(n) begin-time copy.
    pin_cow_saves: int = 0
    device_dispatches: int = 0  # block updates served by the device plane
    device_refreshes: int = 0  # device blocks re-synced from the host iterate
    # --- trace capture (cfg.capture_trace) -------------------------------- #
    trace: Optional[object] = None  # repro.chaos.RunTrace
    # --- telemetry plane (cfg.telemetry) ----------------------------------- #
    telemetry: Optional[object] = None  # repro.telemetry.TelemetryCapture
    # Compact digest (staleness p50/p95, busy-frac series tail, span
    # counts, fire ledger) — small enough to ride every benchmark row.
    telemetry_summary: Optional[dict] = None

    # ------------------------------------------------------------------ #
    def to_dict(self, include_history: bool = True,
                include_x: bool = False) -> dict:
        """JSON-safe dict of this result (the one benchmark row schema).

        ``x`` is omitted unless ``include_x`` (it is O(n)); the trace and
        telemetry capture, when present, serialize through their own
        ``to_dict``.  Round-trips through :meth:`from_dict`.
        """
        out: dict = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "x":
                if include_x:
                    out["x"] = np.asarray(v, dtype=np.float64).tolist()
            elif f.name == "history":
                if include_history:
                    out["history"] = [[float(t), int(wu), float(r)]
                                      for t, wu, r in v]
            elif f.name in ("trace", "telemetry"):
                if v is not None:
                    out[f.name] = v.to_dict() if hasattr(v, "to_dict") else v
            elif f.name == "telemetry_summary":
                if v is not None:
                    out["telemetry_summary"] = dict(v)
            elif f.name == "service_fractions":
                out["service_fractions"] = {
                    str(k): float(sv) for k, sv in (v or {}).items()}
            elif f.name == "error_norm":
                out["error_norm"] = None if v is None else float(v)
            elif isinstance(v, (bool, int, str)) or v is None:
                out[f.name] = v
            else:
                out[f.name] = float(v)
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RunResult":
        """Rebuild a result from :meth:`to_dict` output (e.g. parsed from a
        committed benchmark JSON).  Absent optional payloads come back
        empty: ``x`` as a zero-length array, ``history`` as ``[]``, the
        trace as the raw dict it was serialized to."""
        kw = dict(d)
        kw["x"] = np.asarray(kw.pop("x", []), dtype=np.float64)
        kw["history"] = [(float(t), int(wu), float(r))
                         for t, wu, r in kw.pop("history", [])]
        kw["service_fractions"] = {
            int(k): float(v)
            for k, v in (kw.pop("service_fractions", {}) or {}).items()}
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kw.items() if k in known})

    def summary(self) -> str:
        return (
            f"converged={self.converged} WU={self.worker_updates} "
            f"wall={self.wall_time:.3f}s res={self.residual_norm:.3e} "
            f"fires={self.accel_fires} acc={self.accel_accepts} "
            f"rej={self.accel_rejects} stale_drops={self.stale_drops}"
        )


def _writable(a: np.ndarray) -> np.ndarray:
    """Return a float64 array that is safe to mutate in place.

    Problem maps are jitted JAX functions; ``np.asarray`` of their outputs
    yields read-only buffers, which the coordinator must not adopt directly.
    """
    a = np.asarray(a, dtype=np.float64)
    return a if a.flags.writeable else a.copy()


def _fault_for(cfg: RunConfig, worker: int) -> FaultProfile:
    if cfg.faults is None:
        return FaultProfile()
    if isinstance(cfg.faults, FaultProfile):
        return cfg.faults
    return cfg.faults.get(worker, FaultProfile())
