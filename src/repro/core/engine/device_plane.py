"""Device-resident data plane resolution (``RunConfig.device_plane``).

The device plane keeps each worker's block resident as a JAX array across
the dispatch loop: per dispatch the worker ships only the halo/dependency
slices its block update reads (two g-length rows for Jacobi, the unique
successor closure for VI) instead of re-materializing the O(n) iterate,
and runs the fused block-update(+local-residual) kernel on the resident
block.  :func:`resolve_device_plane` decides whether a run qualifies and
which kernel flavour to use; the *problems* decide per block whether they
can serve it (``FixedPointProblem.device_block_plan``).

Structural requirements (anything else returns None — host path):

* a real backend (``thread`` / ``process``); the virtual backend always
  ignores the knob so fixed-seed virtual runs stay bit-identical to the
  goldens,
* async mode with fixed selection and block returns (the resident block
  IS the worker's fixed block),
* identity projection (a coordinator-side projection rewrites the whole
  iterate after every arrival, so no block stays resident),
* no chaos scenario, controller, or trace capture (membership changes
  reassign blocks mid-run), and no offloaded eval service in the loop
  (``accel_eval="worker"`` keeps the host loop).

``"auto"`` (the default) additionally requires ``n >= AUTO_THRESHOLD``:
below it the halo savings don't pay for the host<->device hops, above it
the O(n) snapshot per dispatch is the dominant cost the plane removes.
"""

from __future__ import annotations

from typing import Optional

from ..fixedpoint import FixedPointProblem
from .types import RunConfig

__all__ = ["AUTO_THRESHOLD", "resolve_device_plane"]

#: "auto" flips the device plane on at this state size (n = 2**20: the
#: per-dispatch O(n) snapshot crosses ~8 MB, which is where BENCH_hotpath
#: shows the copy dominating the block compute on this container).
AUTO_THRESHOLD = 1 << 20

_MODES = ("off", "auto", "on", "jnp", "pallas", "interpret", "ref")


def resolve_device_plane(problem: FixedPointProblem, cfg: RunConfig,
                         backend: str) -> Optional[str]:
    """Kernel flavour (``"jnp"``/``"pallas"``/``"interpret"``/``"ref"``)
    for this run, or None for the host path."""
    mode = getattr(cfg, "device_plane", "off") or "off"
    if mode not in _MODES:
        raise ValueError(
            f"unknown device_plane {mode!r} (expected one of {_MODES})")
    if mode == "off":
        return None
    if backend not in ("thread", "process"):
        return None
    if cfg.mode != "async":
        return None
    if cfg.selection != "fixed" or cfg.return_mode != "block":
        return None
    if (cfg.scenario is not None or cfg.controller is not None
            or cfg.capture_trace or cfg.accel_eval == "worker"):
        return None
    if cfg.checkpoint_every is not None or cfg.resume_from is not None:
        return None
    if not problem.is_projection_trivial():
        return None
    if mode == "auto":
        return "jnp" if problem.n >= AUTO_THRESHOLD else None
    return "jnp" if mode == "on" else mode
