"""Solve sessions: one in-flight request against an executor backend.

A :class:`SolveSession` is the unit the service layer multiplexes: it owns
every piece of per-request state (problem, config, lifecycle, result or
error) so that executor *instances* stay stateless and reentrant — any
number of sessions may execute concurrently against the same backend, and
the multi-interpreter backends share warm pools across them through
:mod:`repro.core.engine.poolreg` leases.

Lifecycle::

    PENDING --start()/execute()--> RUNNING --+--> DONE    (result set)
        |                                    +--> FAILED  (exception set)
        +--cancel()--> CANCELLED   (never started)

``Executor.run()`` is a thin wrapper — ``submit(..., start=False)`` plus an
inline :meth:`SolveSession.execute` on the calling thread — so the default
single-run path goes through exactly the same code as a multiplexed one
(and stays bit-identical to the pre-session engine).  ``start()`` instead
executes on a daemon thread; :meth:`result` joins it.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Optional

from .types import RunConfig, RunResult

__all__ = ["SolveSession", "SessionState"]


class SessionState:
    """String states of a session (kept simple for JSON-friendly stats)."""

    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"


_session_ids = itertools.count(1)


class SolveSession:
    """One solve request: per-run state split out of the executor.

    Created by ``Executor.submit``; not intended for direct construction.
    Thread-safe: any thread may poll :meth:`done`, wait on :meth:`result`,
    or :meth:`cancel` a not-yet-started session while another executes it.
    """

    def __init__(self, executor, problem, cfg: RunConfig):
        self.session_id = next(_session_ids)
        self.executor = executor
        self.problem = problem
        self.cfg = cfg
        self.state = SessionState.PENDING
        self.submitted_s = time.monotonic()
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._result: Optional[RunResult] = None
        self._exception: Optional[BaseException] = None
        self._finished = threading.Event()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def start(self) -> "SolveSession":
        """Execute on a background daemon thread (idempotent error on reuse)."""
        self._transition_to_running()
        self._thread = threading.Thread(
            target=self._execute_locked_stage,
            name=f"solve-session-{self.session_id}", daemon=True)
        self._thread.start()
        return self

    def execute(self) -> RunResult:
        """Execute inline on the calling thread; raises on failure.

        This is the ``run()`` path: no extra thread, identical semantics to
        the pre-session engine including exception propagation.
        """
        self._transition_to_running()
        self._execute_locked_stage()
        if self._exception is not None:
            raise self._exception
        return self._result  # type: ignore[return-value]

    def _transition_to_running(self) -> None:
        with self._lock:
            if self.state != SessionState.PENDING:
                raise RuntimeError(
                    f"session #{self.session_id} already {self.state}; "
                    "sessions execute exactly once")
            self.state = SessionState.RUNNING
            self.started_s = time.monotonic()

    def _execute_locked_stage(self) -> None:
        """Run the backend; record result/exception; never raises itself."""
        try:
            res = self.executor._execute(self)
        except BaseException as e:  # noqa: BLE001 - stored, re-raised in result()
            with self._lock:
                self._exception = e
                self.state = SessionState.FAILED
                self.finished_s = time.monotonic()
        else:
            with self._lock:
                self._result = res
                self.state = SessionState.DONE
                self.finished_s = time.monotonic()
        self._finished.set()

    # ------------------------------------------------------------------ #
    def cancel(self) -> bool:
        """Cancel a session that has not started; True on success."""
        with self._lock:
            if self.state != SessionState.PENDING:
                return False
            self.state = SessionState.CANCELLED
            self.finished_s = time.monotonic()
        self._finished.set()
        return True

    def done(self) -> bool:
        return self._finished.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._finished.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> RunResult:
        """Block until finished and return the RunResult (or re-raise)."""
        if not self._finished.wait(timeout):
            raise TimeoutError(
                f"session #{self.session_id} not finished after {timeout}s")
        if self.state == SessionState.CANCELLED:
            raise RuntimeError(f"session #{self.session_id} was cancelled")
        if self._exception is not None:
            raise self._exception
        return self._result  # type: ignore[return-value]

    def exception(self, timeout: Optional[float] = None):
        """Block until finished; return the stored exception (None if ok)."""
        if not self._finished.wait(timeout):
            raise TimeoutError(
                f"session #{self.session_id} not finished after {timeout}s")
        return self._exception

    @property
    def elapsed_s(self) -> Optional[float]:
        """Execution time (None before start; running time while RUNNING)."""
        if self.started_s is None:
            return None
        end = self.finished_s if self.finished_s is not None else time.monotonic()
        return end - self.started_s

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"SolveSession(#{self.session_id} {self.state} "
                f"executor={getattr(self.executor, 'name', '?')!r} "
                f"mode={self.cfg.mode!r})")
