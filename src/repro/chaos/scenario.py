"""Declarative chaos scenarios: scripted time-varying faults and membership.

A :class:`FaultScenario` is a list of timestamped :class:`ScenarioEvent`\\ s
— profile changes, preemptions, joins, pauses — that the engine backends
interpret against their own clock: *virtual seconds* on the virtual-time
simulator, *wall seconds* on the thread/process/ray backends.  One script
therefore means the same thing everywhere, which is what lets the virtual
backend *predict* a scenario's sync/async behaviour before a real backend
measures it (see ``benchmarks/chaos_scenarios.py``).

Scenario-script grammar
-----------------------
An event is ``(t, kind, worker, profile)`` with ``kind`` one of:

- ``set_profile`` — from time ``t`` the worker (or all workers when
  ``worker`` is None) draws delays/crashes from ``profile`` instead of
  ``RunConfig.faults``;
- ``preempt``     — the worker leaves the membership at ``t``: its
  in-flight result is discarded and its blocks are reassigned to the
  least-loaded survivors (handed back on join);
- ``join``        — the worker (re)joins at ``t`` and takes its home
  block back (plus any orphaned blocks);
- ``pause``       — the worker (or all) stops taking new work after its
  current task; its blocks stay assigned and its in-flight result still
  applies (unlike ``preempt``);
- ``resume``      — a paused worker is dispatched again;
- ``coordinator_crash`` — the control plane itself dies at ``t``:
  the backend raises :class:`repro.recover.CoordinatorCrash` out of the
  run (workers drain into their bounded buffers first on the process
  backend).  Recovery is the serve layer's job — resubmit from the
  latest checkpoint (``ServiceConfig.crash_retries``).

Delay-trace segments (``bimodal_delay``, ``ramp_delay``) are sugar that
compiles down to sequences of ``set_profile`` events, so every backend
interprets them with the same machinery.

Scenarios attach to a run via ``RunConfig.scenario`` (async and sync modes;
``selection="fixed"`` and ``accel_eval="coordinator"`` only).  The
:class:`ScenarioClock` is the tiny interpreter the backends share: it hands
out events whose time has come, in order.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core.engine.types import FaultProfile

__all__ = ["ScenarioEvent", "FaultScenario", "ScenarioClock", "EVENT_KINDS"]

EVENT_KINDS = ("set_profile", "preempt", "join", "pause", "resume",
               "coordinator_crash")


@dataclass
class ScenarioEvent:
    """One timestamped chaos event (see the module grammar)."""

    t: float
    kind: str
    worker: Optional[int] = None  # None => all workers (set_profile/pause/resume)
    profile: Optional[FaultProfile] = None  # set_profile only

    def to_dict(self) -> dict:
        d: dict = {"t": float(self.t), "kind": self.kind}
        if self.worker is not None:
            d["worker"] = int(self.worker)
        if self.profile is not None:
            d["profile"] = dataclasses.asdict(self.profile)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioEvent":
        prof = d.get("profile")
        return cls(
            t=float(d["t"]), kind=d["kind"], worker=d.get("worker"),
            profile=FaultProfile(**prof) if prof is not None else None,
        )


@dataclass
class FaultScenario:
    """An ordered script of chaos events; builder methods chain."""

    name: str = "custom"
    description: str = ""
    events: List[ScenarioEvent] = field(default_factory=list)

    # ------------------------------------------------------------------ #
    # Builders (each returns self so scripts read as one chained block)
    # ------------------------------------------------------------------ #
    def at(self, t: float, kind: str, worker: Optional[int] = None,
           profile: Optional[FaultProfile] = None) -> "FaultScenario":
        if kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown scenario event kind {kind!r}; one of {EVENT_KINDS}")
        self.events.append(ScenarioEvent(t, kind, worker, profile))
        return self

    def set_profile(self, t: float, profile: FaultProfile,
                    worker: Optional[int] = None) -> "FaultScenario":
        return self.at(t, "set_profile", worker, profile)

    def preempt(self, t: float, worker: int) -> "FaultScenario":
        return self.at(t, "preempt", worker)

    def join(self, t: float, worker: int) -> "FaultScenario":
        return self.at(t, "join", worker)

    def pause(self, t: float, worker: Optional[int] = None) -> "FaultScenario":
        return self.at(t, "pause", worker)

    def resume(self, t: float, worker: Optional[int] = None) -> "FaultScenario":
        return self.at(t, "resume", worker)

    def coordinator_crash(self, t: float) -> "FaultScenario":
        """Kill the control plane at ``t`` (raises CoordinatorCrash)."""
        return self.at(t, "coordinator_crash")

    # ------------------------------------------------------------------ #
    # Delay-trace segments (compile to set_profile sequences)
    # ------------------------------------------------------------------ #
    def bimodal_delay(self, t0: float, t1: float, period: float,
                      slow: FaultProfile,
                      fast: Optional[FaultProfile] = None,
                      worker: Optional[int] = None) -> "FaultScenario":
        """Alternate ``slow``/``fast`` profiles every ``period`` over
        ``[t0, t1)`` — the bimodal-straggler regime of Hannah & Yin's
        heterogeneous-delay analysis."""
        if period <= 0:
            raise ValueError("bimodal_delay needs period > 0")
        fast = fast if fast is not None else FaultProfile()
        t, hot = float(t0), True
        while t < t1:
            self.set_profile(t, slow if hot else fast, worker)
            t, hot = t + period, not hot
        self.set_profile(float(t1), fast, worker)
        return self

    def ramp_delay(self, t0: float, t1: float, d0: float, d1: float,
                   steps: int = 8,
                   worker: Optional[int] = None) -> "FaultScenario":
        """Linearly ramp ``delay_mean`` from ``d0`` to ``d1`` over
        ``[t0, t1]`` in ``steps`` piecewise-constant segments."""
        if steps < 1:
            raise ValueError("ramp_delay needs steps >= 1")
        for k in range(steps + 1):
            frac = k / steps
            self.set_profile(
                t0 + frac * (t1 - t0),
                FaultProfile(delay_mean=d0 + frac * (d1 - d0)), worker)
        return self

    # ------------------------------------------------------------------ #
    def sorted_events(self) -> List[ScenarioEvent]:
        """Events by time, ties broken by insertion order (stable sort)."""
        return sorted(self.events, key=lambda ev: ev.t)

    def scaled(self, factor: float) -> "FaultScenario":
        """Same script with every timestamp multiplied by ``factor``
        (stretch a scenario to a slower problem without re-authoring it)."""
        out = FaultScenario(self.name, self.description)
        out.events = [dataclasses.replace(ev, t=ev.t * factor)
                      for ev in self.events]
        return out

    def validate(self, n_workers: int) -> None:
        """Raise ValueError on events no run with ``n_workers`` can honour."""
        for ev in self.events:
            if ev.kind not in EVENT_KINDS:
                raise ValueError(f"unknown event kind {ev.kind!r}")
            if ev.t < 0.0:
                raise ValueError(f"negative event time {ev.t}")
            if ev.kind in ("preempt", "join") and ev.worker is None:
                raise ValueError(f"{ev.kind} needs an explicit worker")
            if ev.kind == "coordinator_crash" and ev.worker is not None:
                raise ValueError(
                    "coordinator_crash kills the control plane, not a "
                    "worker; leave worker unset")
            if ev.worker is not None and not 0 <= ev.worker < n_workers:
                raise ValueError(
                    f"event worker {ev.worker} out of range for "
                    f"n_workers={n_workers} (elastic membership is a subset "
                    "of the configured worker set)")
            if ev.kind == "set_profile" and ev.profile is None:
                raise ValueError("set_profile needs a FaultProfile")

    # ------------------------------------------------------------------ #
    def to_dict(self) -> dict:
        return {"name": self.name, "description": self.description,
                "events": [ev.to_dict() for ev in self.events]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultScenario":
        out = cls(d.get("name", "custom"), d.get("description", ""))
        out.events = [ScenarioEvent.from_dict(e) for e in d.get("events", [])]
        return out


class ScenarioClock:
    """Orders a scenario's events and hands out the ones that are due.

    Backends call :meth:`due` with *their* notion of "now" (virtual seconds
    or wall seconds) at the points where they can act on events, and use
    :meth:`next_time` to bound waits so no event is discovered late.
    """

    def __init__(self, scenario: Optional[FaultScenario]):
        self._events = scenario.sorted_events() if scenario is not None else []
        self._i = 0

    def due(self, now: float) -> List[ScenarioEvent]:
        """Pop (in order) every event with ``t <= now``."""
        out = []
        while self._i < len(self._events) and self._events[self._i].t <= now:
            out.append(self._events[self._i])
            self._i += 1
        return out

    def next_time(self) -> Optional[float]:
        """Timestamp of the next undelivered event, or None when drained."""
        return self._events[self._i].t if self._i < len(self._events) else None

    def drain(self) -> List[ScenarioEvent]:
        """Pop every remaining event (virtual backend: heap-schedule them)."""
        out = self._events[self._i:]
        self._i = len(self._events)
        return out

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._events)
