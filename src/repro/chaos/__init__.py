"""Chaos scenario subsystem: scripted time-varying faults, elastic worker
membership, and deterministic trace capture/replay.

The paper's subject is fault tolerance on *flexible* infrastructure; this
package supplies the flexibility: declarative :class:`FaultScenario`
scripts (timestamped profile changes, preemptions, joins, pauses and
delay-trace segments) interpreted identically by every engine backend
(virtual seconds on the simulator, wall seconds on thread/process/ray), a
registered scenario library (``spot_wave``, ``rolling_restart``,
``bimodal_stragglers``, ``flash_crowd``, ``sdc_storm``), and trace
capture/replay for
postmortem comparison of a measured real-backend run against its
deterministic virtual re-execution.

Entry points:

- attach a scenario:  ``RunConfig(scenario=get_scenario("spot_wave", p))``
- capture a trace:    ``RunConfig(capture_trace=True)`` -> ``RunResult.trace``
- replay it:          ``replay_trace(problem, trace, cfg)``
- compare:            ``trace_agreement(measured, replayed)``

See docs/architecture.md ("Chaos scenarios & elastic membership") and
``benchmarks/chaos_scenarios.py`` / ``BENCH_chaos.json``.
"""

from .library import (
    bimodal_stragglers,
    flash_crowd,
    get_scenario,
    rolling_restart,
    scenario,
    scenario_library,
    sdc_storm,
    spot_wave,
)
from .scenario import EVENT_KINDS, FaultScenario, ScenarioClock, ScenarioEvent
from .trace import RunTrace, TraceRecorder, replay_trace, trace_agreement

__all__ = [
    "ScenarioEvent",
    "FaultScenario",
    "ScenarioClock",
    "EVENT_KINDS",
    "scenario",
    "scenario_library",
    "get_scenario",
    "spot_wave",
    "rolling_restart",
    "bimodal_stragglers",
    "flash_crowd",
    "sdc_storm",
    "RunTrace",
    "TraceRecorder",
    "replay_trace",
    "trace_agreement",
]
