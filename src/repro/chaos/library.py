"""Registered library of chaos scenarios.

Each scenario is a factory ``(n_workers, **knobs) -> FaultScenario``
registered under a stable name.  The library is the contract between the
chaos benchmark (``benchmarks/chaos_scenarios.py`` runs every registered
scenario on the virtual + thread + process backends and commits the
results to ``BENCH_chaos.json``), the README scenario table, and
``tools/docs_check.py`` (which asserts both stay in sync with this
registry).

Default timings assume a run lasting a few seconds on the target backend
(the chaos benchmark's Jacobi configurations); use
:meth:`FaultScenario.scaled` to stretch a script to slower problems.
"""

from __future__ import annotations

from typing import Callable, Dict

from ..core.engine.types import FaultProfile
from .scenario import FaultScenario

__all__ = ["scenario", "scenario_library", "get_scenario",
           "spot_wave", "rolling_restart", "bimodal_stragglers",
           "flash_crowd", "sdc_storm"]

_LIBRARY: Dict[str, dict] = {}


def scenario(name: str, description: str) -> Callable:
    """Register a scenario factory under ``name`` (decorator)."""

    def deco(fn: Callable) -> Callable:
        _LIBRARY[name] = {"factory": fn, "description": description}
        return fn

    return deco


def scenario_library() -> Dict[str, str]:
    """Registered scenario names -> one-line descriptions."""
    return {name: info["description"] for name, info in
            sorted(_LIBRARY.items())}


def get_scenario(name: str, n_workers: int, **kw) -> FaultScenario:
    """Build a registered scenario for a ``n_workers``-worker run."""
    try:
        info = _LIBRARY[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registered: {sorted(_LIBRARY)}"
        ) from None
    scn = info["factory"](n_workers, **kw)
    scn.validate(n_workers)
    return scn


# --------------------------------------------------------------------- #
# The library
# --------------------------------------------------------------------- #
@scenario("spot_wave",
          "spot-instance reclamation: half the fleet is preempted in a "
          "staggered wave and rejoins after a downtime window, while a "
          "surviving worker straggles on the crunched capacity")
def spot_wave(n_workers: int, *, t0: float = 0.5, downtime: float = 1.5,
              stagger: float = 0.1, slow: float = 0.1) -> FaultScenario:
    s = FaultScenario(
        "spot_wave",
        "preemption wave over half the fleet + a straggling survivor")
    lost = list(range(1, max(2, n_workers // 2 + 1)))
    # Capacity crunch: worker 0 survives but straggles from the wave on.
    s.set_profile(t0, FaultProfile(delay_mean=slow), worker=0)
    for k, w in enumerate(lost):
        s.preempt(t0 + k * stagger, w)
    for k, w in enumerate(lost):
        s.join(t0 + downtime + k * stagger, w)
    return s


@scenario("rolling_restart",
          "rolling maintenance: each worker in turn is preempted and "
          "rejoins one downtime later, so the membership is always one "
          "short but never collapses")
def rolling_restart(n_workers: int, *, start: float = 0.3,
                    period: float = 0.6,
                    downtime: float = 0.45) -> FaultScenario:
    if downtime >= period:
        raise ValueError("rolling_restart needs downtime < period "
                         "(windows must not overlap into a full outage)")
    s = FaultScenario("rolling_restart",
                      "one-at-a-time preempt/join across the fleet")
    for w in range(n_workers):
        t = start + w * period
        s.preempt(t, w)
        s.join(t + downtime, w)
    return s


@scenario("bimodal_stragglers",
          "bimodal delay regime: one worker alternates between fast and "
          "100 ms-straggler service periods (time-varying heterogeneous "
          "delays, Hannah & Yin's async-speedup regime)")
def bimodal_stragglers(n_workers: int, *, t0: float = 0.2, t1: float = 4.0,
                       period: float = 0.5,
                       slow: float = 0.1) -> FaultScenario:
    s = FaultScenario("bimodal_stragglers",
                      "alternating fast/slow service on worker 0")
    s.bimodal_delay(t0, t1, period, FaultProfile(delay_mean=slow), worker=0)
    return s


@scenario("sdc_storm",
          "silent-data-corruption storm: a growing fraction of returns "
          "from half the fleet is corrupted (bit-flips ramping in "
          "probability), exercising the coordinator-side SDC guard and "
          "the k-strikes quarantine")
def sdc_storm(n_workers: int, *, t0: float = 0.3, t1: float = 3.0,
              p0: float = 0.02, p1: float = 0.25, steps: int = 4,
              mode: str = "bitflip") -> FaultScenario:
    s = FaultScenario(
        "sdc_storm",
        "ramped corrupt_prob across half the fleet (bit-flip SDC)")
    dirty = list(range(1, max(2, n_workers // 2 + 1)))
    # Piecewise-constant ramp: each step raises corrupt_prob on the dirty
    # subset; clean workers keep the run's baseline profile throughout.
    for k in range(steps + 1):
        frac = k / steps
        prof = FaultProfile(corrupt_prob=p0 + frac * (p1 - p0),
                            corrupt_mode=mode)
        for w in dirty:
            s.set_profile(t0 + frac * (t1 - t0), prof, worker=w)
    return s


@scenario("flash_crowd",
          "elastic scale-up: the run starts on a single worker (the rest "
          "preempted at t=0) and the full fleet joins in a burst, with the "
          "incumbent ramping out of an initial straggle")
def flash_crowd(n_workers: int, *, join_at: float = 0.8,
                stagger: float = 0.05, ramp_from: float = 0.05) -> FaultScenario:
    s = FaultScenario("flash_crowd", "solo start, burst join of the fleet")
    for w in range(1, n_workers):
        s.preempt(0.0, w)
    # The incumbent starts overloaded and ramps back to clean service as
    # the crowd absorbs the load.
    s.ramp_delay(0.0, join_at + 0.5, ramp_from, 0.0, steps=4, worker=0)
    for k, w in enumerate(range(1, n_workers)):
        s.join(join_at + k * stagger, w)
    return s
