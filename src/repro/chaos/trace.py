"""Deterministic trace capture and virtual-time replay.

A real-backend run with ``RunConfig.capture_trace=True`` records its event
trace — dispatches, arrivals (with dispositions and staleness), crashes,
restarts, accel fires, residual records, offloaded evaluations, scenario
events — as it executes.  The trace is the *schedule skeleton* of the run:
it pins the global order of coordinator interactions without storing any
iterate bytes, so it stays small (O(arrivals) dicts) and JSON-serializable
(:class:`RunTrace`).

:func:`replay_trace` re-executes a trace through a fresh coordinator on
virtual time: dispatches re-evaluate the recorded block on the replayed
state, arrivals re-apply in the recorded order with the recorded
dispositions (no rng is consumed), fires re-run the Anderson machine at
the recorded points, and records re-evaluate the residual.  For runs with
inline (coordinator-side) fires and ``noise_std=0`` this reproduces the
measured float trajectory *exactly* — the recorded lock/arrival order is
the only nondeterminism a real backend has — which makes replay a
postmortem microscope: :func:`trace_agreement` quantifies how closely the
replayed residual trajectory tracks the measured one per record point.

Known approximations (documented, not silent):

- ``noise_std > 0`` — the injected noise draws are not recorded, so a
  replayed noisy run diverges from the measured trajectory;
- ``accel_eval="worker"`` traces — offloaded fires are replayed as inline
  fires at their commit position (the pinned-iterate window is collapsed),
  so agreement is approximate rather than bit-exact;
- drop vs staleness filtering is recorded as one ``filtered`` disposition
  (replay counts them all as ``drops``);
- process/ray-backend traces record ``dispatch`` when the coordinator
  *queues* the task, while the worker snapshots the iterate (from shared
  memory / the object store) slightly later — replay evaluates on the
  dispatch-time basis, so agreement on those backends is close but not
  bit-exact.  Thread-backend traces (snapshot under the coordinator lock)
  replay exactly.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..core.engine.coordinator import Coordinator, worker_eval
from ..core.engine.types import FaultProfile, RunConfig, RunResult
from .scenario import ScenarioEvent

__all__ = ["TraceRecorder", "RunTrace", "replay_trace", "trace_agreement",
           "TRACE_EVENT_KINDS"]

TRACE_VERSION = 1

#: Every event kind a :class:`TraceRecorder` can emit.  The telemetry
#: plane keys its ``TRACE_SPAN_MAP`` on this tuple and
#: ``tools/docs_check.py`` asserts the two stay in sync, so adding a
#: trace kind without a telemetry mapping fails the docs gate.
TRACE_EVENT_KINDS = ("dispatch", "arrival", "restart", "fire", "record",
                     "offload", "scenario")


@dataclass
class RunTrace:
    """A captured run trace: schedule metadata + ordered event dicts."""

    meta: dict = field(default_factory=dict)
    events: List[dict] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {"version": TRACE_VERSION, "meta": self.meta,
                "events": self.events}

    @classmethod
    def from_dict(cls, d: dict) -> "RunTrace":
        if d.get("version", TRACE_VERSION) != TRACE_VERSION:
            raise ValueError(f"unsupported trace version {d.get('version')}")
        return cls(meta=dict(d.get("meta", {})),
                   events=list(d.get("events", [])))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "RunTrace":
        return cls.from_dict(json.loads(s))

    def counts(self) -> dict:
        """Event-kind histogram (postmortem at-a-glance)."""
        out: dict = {}
        for ev in self.events:
            out[ev["k"]] = out.get(ev["k"], 0) + 1
        return out


class TraceRecorder:
    """Collects trace events during a run.

    Backends record ``dispatch``/``arrival``/``restart`` at their loop
    sites; the coordinator (when its ``tracer`` attribute is set) records
    ``fire``/``record``/``offload``/``scenario`` events from inside
    ``accel_commit``/``record``/``accel_feed``/``apply_scenario_event`` —
    so every loop that sets a tracer captures the coordinator-side events
    for free, in the exact order they interleave with arrivals.
    """

    def __init__(self, cfg: RunConfig, backend: str,
                 problem: Optional[object] = None):
        self.events: List[dict] = []
        self.meta = {
            "backend": backend,
            "n_workers": cfg.n_workers,
            "seed": cfg.seed,
            "mode": cfg.mode,
            "accel": cfg.accel is not None,
            "accel_eval": cfg.accel_eval,
            "scenario": (cfg.scenario.name
                         if getattr(cfg.scenario, "name", None) else None),
            "controller": getattr(cfg.controller, "name", None),
            "problem": type(problem).__name__ if problem is not None else None,
        }

    # ---- backend-loop hooks ------------------------------------------ #
    def dispatch(self, t: float, worker: int, block: Optional[int],
                 gen: int = 0) -> None:
        """``gen`` is the worker's incarnation (``Coordinator.preempt_gen``)
        at dispatch time; arrivals echo it so replay can match a result to
        its dispatch even when a preempted incarnation's result and a
        rejoined incarnation's dispatch are in flight simultaneously."""
        self.events.append({"k": "dispatch", "t": float(t), "w": int(worker),
                            "b": block if block is None else int(block),
                            "g": int(gen)})

    def arrival(self, t: float, worker: int, disp: str,
                staleness: int = 0, gen: int = 0) -> None:
        self.events.append({"k": "arrival", "t": float(t), "w": int(worker),
                            "d": disp, "s": int(staleness), "g": int(gen)})

    def restart(self, t: float, worker: int) -> None:
        self.events.append({"k": "restart", "t": float(t), "w": int(worker)})

    # ---- coordinator hooks ------------------------------------------- #
    def fire(self, verdict: str, t: Optional[float] = None) -> None:
        ev: dict = {"k": "fire", "v": verdict}
        if t is not None:
            ev["t"] = float(t)
        self.events.append(ev)

    def record(self, t: float, res: float) -> None:
        self.events.append({"k": "record", "t": float(t), "r": float(res)})

    def offload(self, kind: str) -> None:
        self.events.append({"k": "offload", "e": kind})

    def scenario_event(self, t: float, ev: ScenarioEvent) -> None:
        self.events.append({"k": "scenario", "t": float(t),
                            "ev": ev.to_dict()})

    def to_trace(self) -> RunTrace:
        return RunTrace(meta=dict(self.meta), events=self.events)


_NO_FAULT = FaultProfile()


def replay_trace(problem, trace: RunTrace, cfg: RunConfig) -> RunResult:
    """Re-execute a captured trace deterministically on virtual time.

    ``problem`` must be (an equal reconstruction of) the traced problem and
    ``cfg`` the traced run's config — replay reuses its accel settings and
    partitioning but ignores its executor, scenario, and fault channels
    (dispositions come from the trace, so no randomness is consumed).
    """
    import dataclasses as _dc

    if trace.meta.get("mode", "async") != "async":
        raise ValueError("only async traces replay (sync runs are already "
                         "deterministic given the round plan)")
    rcfg = _dc.replace(cfg, executor="virtual", scenario=None,
                       controller=None, capture_trace=False,
                       accel_eval="coordinator", eval_time=None)
    coord = Coordinator(problem, rcfg)
    # In-flight work keyed by (worker, incarnation): within one incarnation
    # a worker has at most one dispatch outstanding, and the incarnation
    # key keeps a preempted result from consuming the entry of a fresh
    # dispatch racing it (preempt + join while a result is in flight).
    pending: dict = {}  # (worker, gen) -> (indices, values)
    t = 0.0
    for ev in trace.events:
        k = ev["k"]
        t = float(ev.get("t", t))
        if k == "dispatch":
            w, b = ev["w"], ev["b"]
            if b is None:
                raise ValueError("trace has a non-fixed-selection dispatch; "
                                 "replay supports selection='fixed' only")
            idx = coord.blocks[b]
            pending[(w, ev.get("g", 0))] = (
                idx, worker_eval(problem, rcfg, coord.x, idx))
        elif k == "arrival":
            w, disp = ev["w"], ev["d"]
            entry = pending.pop((w, ev.get("g", 0)), None)
            if disp == "crash":
                coord.crashes += 1
            elif disp == "preempt_discard":
                coord.preempt_discards += 1
            elif entry is None:
                continue  # truncated trace: arrival without its dispatch
            elif disp == "filtered":
                coord.drops += 1
            else:
                idx, vals = entry
                coord.apply_return(idx, vals, _NO_FAULT,
                                   staleness=int(ev.get("s", 0)), worker=w)
        elif k == "fire":
            coord.maybe_fire_accel()
        elif k == "record":
            coord.record(t)
        elif k == "restart":
            coord.restarts += 1
        elif k == "scenario":
            coord.apply_scenario_event(ScenarioEvent.from_dict(ev["ev"]))
        # "offload" events are postmortem annotations; nothing to replay.
    return coord.result(t, coord.wu, coord.converged())


def trace_agreement(measured: RunResult, replayed: RunResult) -> dict:
    """Per-record measured-over-replay residual-trajectory agreement.

    Compares the two histories index-by-index over their common prefix.
    ``mean_abs_log10_ratio == 0`` is bit-exact agreement; values ≪ 1 mean
    the replay tracks the measured trajectory to well under an order of
    magnitude at every record point.
    """
    mh = [r for (_, _, r) in measured.history]
    rh = [r for (_, _, r) in replayed.history]
    n = min(len(mh), len(rh))
    logs = [abs(math.log10(m / r))
            for m, r in zip(mh[:n], rh[:n]) if m > 0 and r > 0]
    final = (mh[n - 1] / rh[n - 1]) if n and rh[n - 1] > 0 else float("nan")
    return {
        "records_compared": n,
        "records_measured": len(mh),
        "records_replayed": len(rh),
        "mean_abs_log10_ratio": float(np.mean(logs)) if logs else 0.0,
        "max_abs_log10_ratio": float(max(logs)) if logs else 0.0,
        "final_ratio": float(final),
    }
