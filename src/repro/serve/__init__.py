"""Solver-as-a-service layer (paper §4's runtime as a shared resource).

The engine executes one solve per :class:`~repro.core.engine.SolveSession`;
this package multiplexes *many* concurrent requests over it: bounded-queue
admission control, weighted-fair scheduling across tenants, and
same-payload-family batching so concurrent requests share one warm worker
pool with zero respawns (see docs/architecture.md, "Solver-as-a-service").

Quickstart::

    from repro.serve import SolverService, ServiceConfig

    with SolverService(ServiceConfig(max_active=2)) as svc:
        t1 = svc.submit(problem, cfg, tenant="a")
        t2 = svc.submit(problem, cfg, tenant="b")
        r1, r2 = t1.result(), t2.result()
"""

from .scheduler import AdmissionError, FairScheduler, QueuedRequest
from .service import ServiceConfig, SolverService, Ticket, request_family

__all__ = [
    "AdmissionError",
    "FairScheduler",
    "QueuedRequest",
    "ServiceConfig",
    "SolverService",
    "Ticket",
    "request_family",
]
