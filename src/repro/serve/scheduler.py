"""Weighted-fair request scheduling for the solver service.

Start-time fair queuing (SFQ) over tenants: each request is stamped at
admission with a virtual *finish tag*

    start  = max(scheduler vtime, tenant's last finish tag)
    finish = start + cost / weight

and dispatch always picks the smallest finish tag.  A tenant with weight
2 therefore drains twice as fast as a weight-1 tenant under contention,
an idle tenant's first request starts at the current virtual time (no
banked credit), and requests within one tenant stay FIFO.  With a single
tenant the whole thing degenerates to FIFO.

Family affinity is the scheduler-side half of same-payload batching: when
the caller just finished a request of family F, a pending request of the
same family may be picked ahead of the strict fair-order head as long as
its finish tag is within ``affinity_slack`` of the head's — the warm pool
for F is hot *right now*, and a bounded tag detour trades a sliver of
short-term fairness for zero pool churn.  ``affinity_slack=0`` disables
the detour entirely.
"""

from __future__ import annotations

import itertools
import threading
from typing import Dict, Optional

__all__ = ["AdmissionError", "FairScheduler", "QueuedRequest"]


class AdmissionError(RuntimeError):
    """The service's pending queue is full; the request was not accepted."""


class QueuedRequest:
    """One schedulable unit: the solve payload plus fair-queuing stamps."""

    __slots__ = ("tenant", "family", "cost", "ticket", "seq", "tag",
                 "problem", "cfg")

    def __init__(self, tenant: str, family, cost: float, ticket):
        self.tenant = tenant
        self.family = family
        self.cost = float(cost)
        self.ticket = ticket
        self.seq = 0  # admission order; tiebreak for equal tags
        self.tag = 0.0  # virtual finish time; set by the scheduler
        self.problem = None  # set by the service at submit()
        self.cfg = None


class FairScheduler:
    """SFQ queue: push stamps, pop picks min-tag (with affinity detours).

    Not thread-safe by itself — the service serializes access under its
    own condition variable (the scheduler is pure bookkeeping, so there is
    nothing to wait on here).
    """

    def __init__(self, weights: Optional[Dict[str, float]] = None,
                 default_weight: float = 1.0,
                 affinity_slack: float = 0.0):
        if default_weight <= 0.0:
            raise ValueError("default_weight must be positive")
        for t, w in (weights or {}).items():
            if w <= 0.0:
                raise ValueError(f"weight for tenant {t!r} must be positive")
        self.weights = dict(weights or {})
        self.default_weight = float(default_weight)
        self.affinity_slack = float(affinity_slack)
        self._vtime = 0.0
        self._tenant_tag: Dict[str, float] = {}  # last finish tag per tenant
        self._pending: list = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._pending)

    def weight_of(self, tenant: str) -> float:
        return self.weights.get(tenant, self.default_weight)

    def pending_by_tenant(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self._pending:
            out[r.tenant] = out.get(r.tenant, 0) + 1
        return out

    # ------------------------------------------------------------------ #
    def push(self, req: QueuedRequest) -> None:
        """Stamp and enqueue (tags are final: weights apply at admission)."""
        start = max(self._vtime, self._tenant_tag.get(req.tenant, 0.0))
        req.tag = start + req.cost / self.weight_of(req.tenant)
        req.seq = next(self._seq)
        self._tenant_tag[req.tenant] = req.tag
        self._pending.append(req)

    def pop(self, prefer_family=None) -> Optional[QueuedRequest]:
        """Dequeue the fair-order head (or a close same-family request).

        The linear scan is deliberate: pending queues are bounded by the
        service's admission control (tens, not millions), and a heap
        cannot express the affinity detour without lazy deletion.
        """
        if not self._pending:
            return None
        head = min(self._pending, key=lambda r: (r.tag, r.seq))
        pick = head
        if prefer_family is not None and head.family != prefer_family:
            same = [r for r in self._pending
                    if r.family == prefer_family
                    and r.tag <= head.tag + self.affinity_slack]
            if same:
                pick = min(same, key=lambda r: (r.tag, r.seq))
        self._pending.remove(pick)
        # Virtual time follows the dispatched head's *start*; a detour pick
        # does not advance it past work the head still has to do.
        self._vtime = max(self._vtime, min(pick.tag, head.tag))
        return pick

    def remove(self, req: QueuedRequest) -> bool:
        """Withdraw a pending request (cancellation); False if gone."""
        try:
            self._pending.remove(req)
            return True
        except ValueError:
            return False
