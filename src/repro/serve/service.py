"""Solver-as-a-service: multiplex solve requests over shared warm pools.

:class:`SolverService` fronts the session engine with a request queue:

- **admission control** — at most ``max_pending`` queued requests; beyond
  that :meth:`SolverService.submit` raises
  :class:`~repro.serve.scheduler.AdmissionError` immediately instead of
  building unbounded backlog (the caller decides whether to retry/shed);
- **weighted-fair scheduling** — dispatch order across tenants comes from
  :class:`~repro.serve.scheduler.FairScheduler` (start-time fair queuing:
  a weight-2 tenant drains twice as fast under contention, single-tenant
  degenerates to FIFO);
- **same-payload batching** — each dispatcher remembers the payload family
  it just served and asks the scheduler for another request of that family
  (within the fairness slack), so back-to-back requests ride one warm
  worker pool.  Pool *sharing* itself is the engine's job: sessions of one
  family hold refcounted leases on a single pool
  (:mod:`repro.core.engine.poolreg`) whichever order they dispatch in —
  affinity just minimizes run-lock interleaving and LRU churn;
- **sessions** — every request executes as its own
  :class:`~repro.core.engine.session.SolveSession` on one of
  ``max_active`` dispatcher threads, so the backends' reentrancy does the
  actual multiplexing.

The service is deliberately in-process (a library object, not a server):
the benchmark and the launch CLI drive it directly, and anything
network-facing can wrap :meth:`submit`/:meth:`Ticket.result` 1:1.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional

from ..core.engine import RunConfig, RunResult, get_executor
from ..core.engine.coordinator import problem_payload
from ..core.engine.poolreg import payload_key
from ..core.engine.types import CoordinatorCrash
from ..recover import latest_checkpoint, resume_config
from .scheduler import AdmissionError, FairScheduler, QueuedRequest

__all__ = ["ServiceConfig", "SolverService", "Ticket"]


@dataclass
class ServiceConfig:
    """Knobs for one :class:`SolverService` instance."""

    max_active: int = 2  # dispatcher threads == concurrently running solves
    max_pending: int = 64  # queue bound; beyond it submit() raises
    weights: Dict[str, float] = field(default_factory=dict)  # tenant -> weight
    default_weight: float = 1.0  # weight for tenants not listed
    family_affinity: bool = True  # batch same-payload requests per dispatcher
    affinity_slack: float = 0.5  # max virtual-tag detour for an affinity pick
    # Coordinator-crash recovery: when a dispatched solve dies with
    # CoordinatorCrash and the request was checkpointing
    # (cfg.checkpoint_dir), resubmit it from the latest checkpoint up to
    # this many times before failing the ticket.  Commits are at-most-once:
    # checkpoints are written at arrival boundaries, so work applied after
    # the snapshot is redone by the resumed run, never double-counted.
    crash_retries: int = 0
    # Service-plane telemetry: a recorder on the service itself collecting
    # one "serve" span per request (admission -> dispatch -> finish, with
    # tenant and queueing delay) and a queue-depth series.  Feeds
    # repro.telemetry.export.to_prometheus; independent of any per-run
    # RunConfig.telemetry the requests may carry.
    telemetry: bool = False

    def __post_init__(self) -> None:
        if self.max_active < 1:
            raise ValueError("max_active must be >= 1")
        if self.max_pending < 1:
            raise ValueError("max_pending must be >= 1")


class Ticket:
    """Caller's handle on one submitted request (future-like).

    Timing fields are ``time.monotonic`` stamps: ``queued_s`` at admission,
    ``dispatched_s`` when a dispatcher picked it up, ``finished_s`` when the
    result (or error) landed — ``dispatched_s - queued_s`` is queueing
    delay, ``finished_s - dispatched_s`` is service time.
    """

    def __init__(self, tenant: str, family):
        self.tenant = tenant
        self.family = family
        self.queued_s = time.monotonic()
        self.dispatched_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._done = threading.Event()
        self._result: Optional[RunResult] = None
        self._exception: Optional[BaseException] = None
        self._cancelled = False
        self._request: Optional[QueuedRequest] = None  # set by the service
        self._service: Optional["SolverService"] = None

    # -- service side -------------------------------------------------- #
    def _finish(self, result=None, exception=None) -> None:
        self._result = result
        self._exception = exception
        self.finished_s = time.monotonic()
        self._done.set()

    # -- caller side --------------------------------------------------- #
    def cancel(self) -> bool:
        """Withdraw the request if it has not been dispatched yet."""
        svc = self._service
        if svc is None:
            return False
        with svc._cond:
            if self._done.is_set() or self.dispatched_s is not None:
                return False
            svc._scheduler.remove(self._request)
            self._cancelled = True
            self._finish()
            svc._cond.notify_all()
        return True

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> RunResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not finished after {timeout}s")
        if self._cancelled:
            raise RuntimeError("request was cancelled")
        if self._exception is not None:
            raise self._exception
        return self._result  # type: ignore[return-value]

    @property
    def wait_s(self) -> Optional[float]:
        """Queueing delay (None until dispatched)."""
        if self.dispatched_s is None:
            return None
        return self.dispatched_s - self.queued_s

    @property
    def total_s(self) -> Optional[float]:
        """Admission-to-result latency (None until finished)."""
        if self.finished_s is None:
            return None
        return self.finished_s - self.queued_s


def request_family(problem, cfg: RunConfig):
    """Stable payload-family key for batching/affinity decisions.

    Same key as the engine's pool registry wherever the problem can cross
    process boundaries; problems that cannot (no factory_spec, unpicklable)
    fall back to instance identity — they never pool anyway.
    """
    try:
        return payload_key(problem_payload(problem), cfg)
    except Exception:
        return (f"obj:{id(problem)}", cfg.n_workers, cfg.return_mode)


class SolverService:
    """In-process solve-request multiplexer over the session engine."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.config = config or ServiceConfig()
        self._scheduler = FairScheduler(
            weights=self.config.weights,
            default_weight=self.config.default_weight,
            affinity_slack=(self.config.affinity_slack
                            if self.config.family_affinity else 0.0))
        self._cond = threading.Condition()
        self._closed = False
        self._active = 0
        self._served: Dict[str, int] = {}  # tenant -> completed requests
        self._failed = 0
        self._rejected = 0
        self._crash_resumes = 0  # coordinator crashes resumed from checkpoint
        self.telemetry = None
        self._tel_t0 = time.monotonic()
        if self.config.telemetry:
            from ..telemetry import TelemetryRecorder

            self.telemetry = TelemetryRecorder(
                meta={"service": True,
                      "max_active": self.config.max_active})
            self.telemetry.install_clock(
                lambda: time.monotonic() - self._tel_t0)
        self._dispatchers = [
            threading.Thread(target=self._dispatch_loop, args=(i,),
                             name=f"solver-serve-{i}", daemon=True)
            for i in range(self.config.max_active)
        ]
        for th in self._dispatchers:
            th.start()

    # ------------------------------------------------------------------ #
    def submit(self, problem, cfg: RunConfig, tenant: str = "default",
               cost: float = 1.0) -> Ticket:
        """Admit one solve request; returns immediately with a Ticket.

        Raises :class:`AdmissionError` when the pending queue is full and
        RuntimeError after :meth:`close` — submission never blocks.
        """
        family = request_family(problem, cfg)
        ticket = Ticket(tenant, family)
        req = QueuedRequest(tenant, family, cost, ticket)
        req.problem, req.cfg = problem, cfg
        ticket._request = req
        ticket._service = self
        with self._cond:
            if self._closed:
                raise RuntimeError("service is closed")
            if len(self._scheduler) >= self.config.max_pending:
                self._rejected += 1
                raise AdmissionError(
                    f"pending queue full ({self.config.max_pending}); "
                    "request rejected")
            self._scheduler.push(req)
            if self.telemetry is not None:
                self.telemetry.series_point(
                    "queue_depth", self.telemetry.now(),
                    len(self._scheduler))
            self._cond.notify()
        return ticket

    def _tel_finish(self, req, ok: bool) -> None:
        """Emit the request's serve span (caller holds ``_cond``).

        Ticket stamps are ``time.monotonic``; the span rebases them onto
        the service clock so every request shares one timeline origin.
        """
        tel = self.telemetry
        if tel is None:
            return
        tk = req.ticket
        t1 = tk.finished_s if tk.finished_s is not None else time.monotonic()
        tel.span("serve", f"tenant:{tk.tenant}",
                 tk.queued_s - self._tel_t0, t1 - self._tel_t0,
                 tenant=tk.tenant, ok=ok,
                 wait_s=tk.wait_s if tk.wait_s is not None else 0.0)
        tel.series_point("queue_depth", tel.now(), len(self._scheduler))

    def _dispatch_loop(self, i: int) -> None:
        last_family = None
        while True:
            with self._cond:
                req = None
                while not self._closed:
                    req = self._scheduler.pop(
                        prefer_family=(last_family
                                       if self.config.family_affinity
                                       else None))
                    if req is not None:
                        break
                    self._cond.wait()
                if req is None:  # closed with an empty queue
                    return
                self._active += 1
                req.ticket.dispatched_s = time.monotonic()
            try:
                if req.cfg.controller is not None:
                    # Close the serve->autoscale loop: the controller's
                    # signal probe reads this service's backlog, so a
                    # policy can scale membership with admission pressure.
                    req.cfg.controller.queue_depth_fn = (
                        lambda: len(self._scheduler))
                result = self._run_request(req)
            except BaseException as e:  # noqa: BLE001 - delivered via ticket
                with self._cond:
                    self._active -= 1
                    self._failed += 1
                    req.ticket._finish(exception=e)
                    self._tel_finish(req, ok=False)
                    self._cond.notify_all()
            else:
                with self._cond:
                    self._active -= 1
                    self._served[req.tenant] = (
                        self._served.get(req.tenant, 0) + 1)
                    req.ticket._finish(result=result)
                    self._tel_finish(req, ok=True)
                    self._cond.notify_all()
            last_family = req.family

    def _run_request(self, req) -> RunResult:
        """Execute one request, resuming through coordinator crashes.

        A dispatched solve that dies with :class:`CoordinatorCrash` is
        resubmitted from the latest checkpoint in ``cfg.checkpoint_dir``
        (``ServiceConfig.crash_retries`` attempts) before the ticket
        fails.  :func:`repro.recover.resume_config` strips the scenario —
        the script's remaining events died with the control plane — so a
        scripted crash cannot re-kill the resumed attempt.
        """
        cfg = req.cfg
        attempt = 0
        while True:
            try:
                session = get_executor(cfg.executor).submit(
                    req.problem, cfg, start=False)
                return session.execute()
            except CoordinatorCrash:
                if (attempt >= self.config.crash_retries
                        or req.cfg.checkpoint_dir is None):
                    raise
                ckpt = latest_checkpoint(req.cfg.checkpoint_dir)
                if ckpt is None:  # crashed before the first checkpoint
                    raise
                attempt += 1
                with self._cond:
                    self._crash_resumes += 1
                cfg = resume_config(req.cfg, ckpt)

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, object]:
        with self._cond:
            return {
                "pending": len(self._scheduler),
                "pending_by_tenant": self._scheduler.pending_by_tenant(),
                "active": self._active,
                "served": dict(self._served),
                "failed": self._failed,
                "rejected": self._rejected,
                "crash_resumes": self._crash_resumes,
                "max_active": self.config.max_active,
                "closed": self._closed,
            }

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until queue and dispatchers are idle; False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while len(self._scheduler) > 0 or self._active > 0:
                wait = (None if deadline is None
                        else deadline - time.monotonic())
                if wait is not None and wait <= 0:
                    return False
                self._cond.wait(wait)
            return True

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop accepting requests; by default finish what is queued.

        With ``drain=False`` pending (undispatched) requests are cancelled;
        running solves always complete — sessions have no preemption.
        """
        if drain:
            self.drain(timeout)
        with self._cond:
            self._closed = True
            if not drain:
                while True:
                    req = self._scheduler.pop()
                    if req is None:
                        break
                    req.ticket._cancelled = True
                    req.ticket._finish()
            self._cond.notify_all()
        for th in self._dispatchers:
            th.join(timeout=5.0)

    def __enter__(self) -> "SolverService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
