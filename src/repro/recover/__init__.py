"""Durable solves: checkpoint/restore, crash recovery, SDC quarantine.

Three failure domains, one subsystem:

- **Checkpoint/restore** — :class:`SolveCheckpoint` snapshots the full
  coordinator state (iterate, rng, Anderson window + Gram, membership,
  accounting) plus the backend's resumable loop state at arrival
  boundaries; :func:`resume_fixed_point` reconstructs the session on any
  backend, bit-identically on virtual/thread.
- **Coordinator crash recovery** — the ``coordinator_crash`` chaos event
  raises :class:`CoordinatorCrash` out of the control plane; the serve
  layer's retry policy (``ServiceConfig.crash_retries``) catches it and
  resubmits from the latest checkpoint with at-most-once commits.
- **SDC quarantine** — ``FaultProfile.corrupt_prob`` injects bit-flip /
  NaN / scale corruption at worker returns; the coordinator-side guard
  (``RunConfig.sdc_guard``) screens NaN/Inf and residual-divergent
  arrivals and quarantines repeat offenders (``RunConfig.sdc_strikes``)
  through the elastic-membership preempt machinery.

See docs/architecture.md "Failure domains & recovery".
"""

from ..core.engine.types import CoordinatorCrash
from .checkpoint import (
    SolveCheckpoint,
    capture,
    latest_checkpoint,
    list_checkpoints,
    resolve_checkpoint,
    restore_coordinator,
    write_checkpoint,
)
from .resume import resume_config, resume_fixed_point, submit_resume

__all__ = [
    "CoordinatorCrash",
    "SolveCheckpoint",
    "capture",
    "write_checkpoint",
    "latest_checkpoint",
    "list_checkpoints",
    "resolve_checkpoint",
    "restore_coordinator",
    "resume_config",
    "resume_fixed_point",
    "submit_resume",
]
