"""Resume a durable solve from its latest SolveCheckpoint.

:func:`resume_fixed_point` is the one-call restore path: give it the
problem, the original config, and (optionally) a specific checkpoint, and
it reconstructs the session on whatever backend the config names.  The
contract mirrors :func:`repro.core.engine.run_fixed_point`, plus:

- the resumed run picks up *exactly* where the checkpoint left off — on
  the virtual and thread backends the continuation is bit-identical to an
  uninterrupted run (the checkpoint carries the rng state, the Anderson
  window and the backend's loop state);
- commit semantics across the restore boundary are at-most-once: arrivals
  applied after the snapshot were never committed into it and are redone,
  never double-counted, and no accel fire replays;
- control-plane attachments die with the control plane: a scenario
  script, autoscale controller, or trace capture configured on the
  original run is stripped from the resume config (their remaining
  events/state lived in the crashed coordinator).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.engine import run_fixed_point, submit_fixed_point
from ..core.engine.types import RunConfig, RunResult
from .checkpoint import SolveCheckpoint, latest_checkpoint, resolve_checkpoint

__all__ = ["resume_config", "resume_fixed_point", "submit_resume"]


def resume_config(cfg: RunConfig,
                  ckpt: Optional[SolveCheckpoint] = None) -> RunConfig:
    """Build the config for a resumed run.

    Locates the newest checkpoint under ``cfg.checkpoint_dir`` when
    ``ckpt`` is not given, installs it as ``resume_from``, and strips the
    control-plane attachments (scenario / controller / capture_trace)
    that cannot survive a coordinator loss.  Checkpointing itself stays
    on, so the resumed run keeps extending the same checkpoint chain.
    """
    if ckpt is None:
        if not cfg.checkpoint_dir:
            raise ValueError(
                "resume_fixed_point needs a checkpoint: pass one, or a cfg "
                "with checkpoint_dir set")
        ckpt = latest_checkpoint(cfg.checkpoint_dir)
        if ckpt is None:
            raise FileNotFoundError(
                f"no checkpoints under {cfg.checkpoint_dir!r}")
    else:
        ckpt = resolve_checkpoint(ckpt)
    return dataclasses.replace(
        cfg, resume_from=ckpt, scenario=None, controller=None,
        capture_trace=False)


def resume_fixed_point(problem, cfg: RunConfig,
                       ckpt: Optional[SolveCheckpoint] = None) -> RunResult:
    """Reconstruct and finish a checkpointed solve (blocking)."""
    return run_fixed_point(problem, resume_config(cfg, ckpt))


def submit_resume(problem, cfg: RunConfig,
                  ckpt: Optional[SolveCheckpoint] = None):
    """Session-surface twin of :func:`resume_fixed_point`: returns a
    started :class:`repro.core.engine.SolveSession`."""
    return submit_fixed_point(problem, resume_config(cfg, ckpt))
