"""SolveCheckpoint: a consistent on-disk snapshot of a running solve.

A checkpoint captures everything the coordinator owns at one arrival
boundary — the iterate ``x``, the rng state, the Anderson/DIIS window,
the elastic-membership assignment, the SDC-guard state and every
accounting counter — plus the backend's own resumable loop state (the
virtual backend's event heap; cadence counters elsewhere).  Arrival
boundaries are the engine's consistency points: no apply, fire or record
is mid-flight, so restoring the snapshot is exact, with at-most-once
commit semantics — work applied after the checkpoint was never committed
into it and is simply redone, never double-counted.

On-disk format: ``<dir>/<tag>.json`` (scalars, membership, history, rng
state) plus ``<tag>.npz`` (the iterate, the Anderson window rows, heap
payload arrays).  Writes are atomic (tmp + rename), so a crash mid-write
never leaves a half checkpoint as the latest one.
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..core.engine.types import FaultProfile

__all__ = [
    "SolveCheckpoint",
    "write_checkpoint",
    "latest_checkpoint",
    "list_checkpoints",
    "resolve_checkpoint",
    "restore_coordinator",
]

FORMAT_VERSION = 1

#: Coordinator counters checkpointed / restored verbatim (all JSON scalars).
_COUNTERS = (
    "wu", "drops", "stale_drops", "crashes", "restarts",
    "staleness_sum", "staleness_n", "coordinator_evals", "arrivals",
    "since_record", "offloaded_evals", "accel_discards", "busy_s",
    "fire_window_s", "fire_window_arrivals", "_x_version", "_res_version",
    "res_norm", "preemptions", "joins", "reassigned_blocks",
    "preempt_discards", "_membership_version", "accel_partial_commits",
    "sdc_rejects", "quarantined", "checkpoints_written", "controller_actions",
)


@dataclass
class SolveCheckpoint:
    """One loaded (or about-to-be-written) checkpoint.

    ``meta`` is the JSON document; ``arrays`` the npz payload.  ``tag`` is
    the checkpoint's identity (``ckpt-<wu>``), recorded on the resumed
    run's ``RunResult.resumed_from``.
    """

    meta: dict
    arrays: Dict[str, np.ndarray] = field(default_factory=dict)
    path: Optional[str] = None  # the .json path once saved/loaded

    @property
    def tag(self) -> str:
        return self.meta["tag"]

    @property
    def wu(self) -> int:
        return int(self.meta["wu"])

    @property
    def t(self) -> float:
        return float(self.meta["t"])

    @property
    def loop(self) -> dict:
        """The backend loop state captured with the snapshot (may be {})."""
        return self.meta.get("loop") or {}

    # ------------------------------------------------------------------ #
    def save(self, directory: str) -> str:
        """Write ``<tag>.json`` + ``<tag>.npz`` atomically; returns the
        json path."""
        os.makedirs(directory, exist_ok=True)
        base = os.path.join(directory, self.tag)
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **self.arrays)
            os.replace(tmp, base + ".npz")
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        fd, tmp = tempfile.mkstemp(dir=directory, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.meta, f)
            os.replace(tmp, base + ".json")
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        self.path = base + ".json"
        return self.path

    @classmethod
    def load(cls, path: str) -> "SolveCheckpoint":
        """Load from a ``.json`` path (the sibling ``.npz`` rides along)."""
        if path.endswith(".npz"):
            path = path[:-4] + ".json"
        with open(path) as f:
            meta = json.load(f)
        if meta.get("format") != FORMAT_VERSION:
            raise ValueError(
                f"unsupported checkpoint format {meta.get('format')!r} "
                f"in {path} (expected {FORMAT_VERSION})")
        arrays: Dict[str, np.ndarray] = {}
        npz_path = path[:-5] + ".npz"
        with np.load(npz_path) as z:
            for k in z.files:
                arrays[k] = z[k]
        return cls(meta=meta, arrays=arrays, path=path)


def list_checkpoints(directory: str) -> list:
    """All checkpoint json paths under ``directory``, oldest first."""
    if not os.path.isdir(directory):
        return []
    names = [n for n in os.listdir(directory)
             if n.startswith("ckpt-") and n.endswith(".json")]
    return [os.path.join(directory, n) for n in sorted(names)]


def latest_checkpoint(directory: str) -> Optional[SolveCheckpoint]:
    """Load the most recent checkpoint in ``directory`` (None if empty)."""
    paths = list_checkpoints(directory)
    return SolveCheckpoint.load(paths[-1]) if paths else None


def resolve_checkpoint(ref) -> SolveCheckpoint:
    """Normalize ``RunConfig.resume_from``: a SolveCheckpoint passes
    through, a path to a ``.json`` (or a checkpoint directory) loads."""
    if isinstance(ref, SolveCheckpoint):
        return ref
    if isinstance(ref, str):
        if os.path.isdir(ref):
            ckpt = latest_checkpoint(ref)
            if ckpt is None:
                raise FileNotFoundError(f"no checkpoints under {ref!r}")
            return ckpt
        return SolveCheckpoint.load(ref)
    raise TypeError(
        f"resume_from must be a SolveCheckpoint or a path, got {type(ref)}")


# --------------------------------------------------------------------- #
# Capture
# --------------------------------------------------------------------- #
def capture(coord, t: float, loop_state=None) -> SolveCheckpoint:
    """Snapshot a coordinator (plus optional backend loop state) into an
    in-memory SolveCheckpoint.  ``loop_state`` is ``None`` or a
    ``(meta_dict, arrays_dict)`` pair from the backend's loop."""
    meta: dict = {
        "format": FORMAT_VERSION,
        "tag": f"ckpt-{coord.wu:08d}",
        "t": float(t),
        "wu": int(coord.wu),
        "executor": coord.cfg.executor,
        "seed": int(coord.cfg.seed),
        "n_workers": int(coord.cfg.n_workers),
        "rng": _jsonable(coord.rng.bit_generator.state),
        "history": [[float(ht), int(hw), float(hr)]
                    for ht, hw, hr in coord.history],
        "counters": {},
        "membership": {
            "active": sorted(coord.active),
            "paused": sorted(coord.paused),
            "worker_blocks": {str(w): list(bs)
                              for w, bs in coord.worker_blocks.items()},
            "block_owner": {str(b): int(w)
                            for b, w in coord.block_owner.items()},
            "orphan_blocks": list(coord._orphan_blocks),
            "rr": {str(w): int(c) for w, c in coord._rr.items()},
            "preempt_gen": {str(w): int(g)
                            for w, g in coord.preempt_gen.items()},
            "applied_by_worker": {str(w): int(c)
                                  for w, c in coord.applied_by_worker.items()},
            "block_moved_at": {str(b): int(v)
                               for b, v in coord._block_moved_at.items()},
            "scenario_down": sorted(coord.scenario_down),
            "live_profiles": {str(w): dataclasses.asdict(p)
                              for w, p in coord.live_profiles.items()},
        },
        "sdc": {
            "norms": [float(v) for v in coord._sdc_norms],
            "strikes": {str(w): int(s)
                        for w, s in coord._sdc_strikes.items()},
            # Block keys are (start, stop, step)/(first, last, size)
            # tuples; flatten to [k0, k1, k2, count] rows for JSON.
            "block_rejects": [[*k, int(n)] for k, n in
                              coord._sdc_block_rejects.items()],
        },
        "loop": None,
        "arrays": [],
    }
    for name in _COUNTERS:
        v = getattr(coord, name)
        meta["counters"][name] = (
            int(v) if isinstance(v, (int, np.integer)) else float(v))
    arrays: Dict[str, np.ndarray] = {"x": np.asarray(coord.x, np.float64)}
    if coord.accel is not None:
        snap = coord.accel.snapshot()
        meta["accel"] = {k: snap[k] for k in
                         ("n_accept", "n_reject", "n_fire")}
        meta["accel"]["has_window"] = "X" in snap
        if snap.get("last_alpha") is not None:
            arrays["accel_last_alpha"] = snap["last_alpha"]
        for k in ("X", "G", "F"):
            if k in snap:
                arrays[f"accel_{k}"] = snap[k]
    if loop_state is not None:
        loop_meta, loop_arrays = loop_state
        meta["loop"] = loop_meta
        arrays.update(loop_arrays)
    meta["arrays"] = sorted(arrays)
    return SolveCheckpoint(meta=meta, arrays=arrays)


def write_checkpoint(coord, t: float, loop_state=None) -> str:
    """Capture + save under ``coord.cfg.checkpoint_dir`` (the hook
    :meth:`Coordinator.maybe_checkpoint` calls)."""
    return capture(coord, t, loop_state).save(coord.cfg.checkpoint_dir)


def _jsonable(obj):
    """Recursively convert a bit_generator state dict to JSON scalars."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def _rng_state(obj):
    """Inverse of :func:`_jsonable` for bit_generator state: numpy's
    setters accept plain ints/lists, so this is a pass-through."""
    return obj


# --------------------------------------------------------------------- #
# Restore
# --------------------------------------------------------------------- #
def restore_coordinator(coord, ckpt: SolveCheckpoint) -> None:
    """Load a checkpoint into a freshly constructed Coordinator.

    The coordinator must have been built from the *same problem and an
    equivalent config* (same partition, same accel settings) — the
    checkpoint stores solver state, not the problem operator.  After this
    the backend seeds its loop from ``ckpt.loop`` and runs; counters pick
    up exactly where the snapshot left them (at-most-once: post-snapshot
    work was never committed and is redone).
    """
    meta = ckpt.meta
    if int(meta["n_workers"]) != coord.cfg.n_workers:
        raise ValueError(
            f"checkpoint was taken with n_workers={meta['n_workers']}, "
            f"resume config has {coord.cfg.n_workers}")
    x = np.asarray(ckpt.arrays["x"], np.float64)
    if x.shape != coord.x.shape:
        raise ValueError(
            f"checkpoint iterate has shape {x.shape}, problem produces "
            f"{coord.x.shape} — wrong problem?")
    coord.x = x.copy()
    coord.rng.bit_generator.state = _rng_state(meta["rng"])
    for name, v in meta["counters"].items():
        setattr(coord, name, v)
    coord.history = [(float(ht), int(hw), float(hr))
                     for ht, hw, hr in meta["history"]]
    mem = meta["membership"]
    coord.active = set(mem["active"])
    coord.paused = set(mem["paused"])
    coord.worker_blocks = {int(w): list(bs)
                           for w, bs in mem["worker_blocks"].items()}
    coord.block_owner = {int(b): int(w)
                         for b, w in mem["block_owner"].items()}
    coord._orphan_blocks = list(mem["orphan_blocks"])
    coord._rr = {int(w): int(c) for w, c in mem["rr"].items()}
    coord.preempt_gen = {int(w): int(g)
                         for w, g in mem["preempt_gen"].items()}
    coord.applied_by_worker = {int(w): int(c)
                               for w, c in mem["applied_by_worker"].items()}
    coord._block_moved_at = {int(b): int(v)
                             for b, v in mem["block_moved_at"].items()}
    coord.scenario_down = set(mem["scenario_down"])
    coord.live_profiles = {int(w): FaultProfile(**p)
                           for w, p in mem["live_profiles"].items()}
    sdc = meta.get("sdc") or {}
    coord._sdc_norms = [float(v) for v in sdc.get("norms", [])]
    coord._sdc_strikes = {int(w): int(s)
                          for w, s in sdc.get("strikes", {}).items()}
    coord._sdc_block_rejects = {
        tuple(None if k is None else int(k) for k in rowv[:-1]): int(rowv[-1])
        for rowv in sdc.get("block_rejects", [])}
    if coord.accel is not None and "accel" in meta:
        snap = dict(meta["accel"])
        snap["last_alpha"] = ckpt.arrays.get("accel_last_alpha")
        for k in ("X", "G", "F"):
            if f"accel_{k}" in ckpt.arrays:
                snap[k] = ckpt.arrays[f"accel_{k}"]
        coord.accel.restore(snap)
    # Resume provenance + cadence: never rewrite the checkpoint we resumed
    # from at the same wu.
    coord.resumed_from = ckpt.tag
    coord._last_ckpt_wu = int(meta["wu"])
    tel = getattr(coord, "telemetry", None)
    if tel is not None:
        tel.instant("restore", "coord", float(ckpt.t),
                    tag=str(ckpt.tag), wu=int(meta["wu"]))
