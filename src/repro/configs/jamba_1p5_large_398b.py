"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887; hf].

Hybrid Mamba+attention at 1:7 attn:mamba, MoE (16 experts, top-2) on every
second layer.  8-layer period: attention at position 4, MoE on odd
positions; 72 layers = 9 periods.  Verified param count ~398B (DESIGN.md).
"""

from .base import MambaConfig, ModelConfig, MoEConfig

_PERIOD = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    period=_PERIOD,
    moe=MoEConfig(n_experts=16, top_k=2, pad_to=16),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    tie_embeddings=False,
    ffn_act="swiglu",
    rope_theta=1e6,
    sub_quadratic=True,
    opt_state_dtype="bfloat16",  # fits 16GB HBM at 256 chips (DESIGN §8)
    source="arXiv:2403.19887",
)
