"""Whisper-large-v3 (1.55B) [arXiv:2212.04356].

Encoder-decoder; the conv frontend is a stub — ``input_specs()`` supplies
post-conv frame embeddings (B, frames, d_model).  Sinusoidal positions,
GELU MLP.  ``n_layers`` is the decoder depth; encoder depth matches.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    kind="encdec",
    n_layers=32,
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    period=(("attn", "mlp"),),
    ffn_act="gelu",
    pos_embed="sinusoidal",
    tie_embeddings=True,
    audio_stub=True,
    source="arXiv:2212.04356",
)
