"""Qwen1.5-MoE-A2.7B (14.3B total / 2.7B active) [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts (top-4, d_ff 1408) + 4 shared experts (combined 5632).
60 experts are padded to 64 for clean EP=16 sharding; router masks padding
(DESIGN.md §8.3).
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, pad_to=16),
    ffn_act="swiglu",
    rope_theta=1e6,
    tie_embeddings=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
