"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

Transformer backbone only; dynamic-resolution vision frontend is a stub —
``input_specs()`` supplies precomputed patch embeddings + merge mask +
M-RoPE (temporal/height/width) position ids with sections (16, 24, 24).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    period=(("attn", "mlp"),),
    ffn_act="swiglu",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    tie_embeddings=False,
    vision_stub=True,
    opt_state_dtype="bfloat16",
    source="arXiv:2409.12191",
)
