"""Architecture configuration schema + registry.

Each assigned architecture gets one module in :mod:`repro.configs` exporting
``CONFIG``; ``get_config(name)`` resolves by id.  Layer stacks are expressed
as a repeating *period* of (mixer, ffn) sublayer pairs plus an optional
remainder, so heterogeneous patterns (Jamba 1:7 attn:mamba with MoE every
2nd layer, Gemma local:global alternation) scan efficiently.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# mixer kinds: "attn" (global), "local" (sliding window), "mamba",
#              "mlstm", "slstm"
# ffn kinds:   "mlp", "moe", "none"
Sublayer = Tuple[str, str]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0  # shared (always-on) experts, Qwen-MoE style
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_z_coef: float = 1e-3
    # Experts padded up to a multiple of this for clean EP sharding; the
    # router masks the padding (see DESIGN.md §8.3).
    pad_to: int = 1
    # Explicit shard_map all-to-all dispatch (models/moe_shard_map.py);
    # GSPMD's gather-based fallback replicates expert compute over the data
    # axis or blows up collectives (EXPERIMENTS.md §Perf).
    a2a: bool = False

    @property
    def padded_experts(self) -> int:
        r = self.n_experts % self.pad_to
        return self.n_experts + (self.pad_to - r if r else 0)


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default ceil(d_model / 16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- layer pattern ------------------------------------------------- #
    period: Tuple[Sublayer, ...] = (("attn", "mlp"),)
    # --- attention ----------------------------------------------------- #
    pos_embed: str = "rope"  # rope | sinusoidal (whisper)
    rope_theta: float = 10_000.0
    window: Optional[int] = None  # sliding window for "local" mixers
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    mrope_sections: Optional[Tuple[int, int, int]] = None  # Qwen2-VL M-RoPE
    qk_norm: bool = False
    # --- ffn ------------------------------------------------------------ #
    ffn_act: str = "swiglu"  # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # --- embeddings / output -------------------------------------------- #
    tie_embeddings: bool = True
    scale_embed: bool = False  # Gemma-style sqrt(d_model) input scaling
    # --- enc-dec (whisper) ----------------------------------------------- #
    kind: str = "decoder"  # decoder | encdec
    n_enc_layers: int = 0
    cross_every: int = 1
    # --- vlm stub --------------------------------------------------------- #
    vision_stub: bool = False
    audio_stub: bool = False
    # --- long-context chunking (memory-bounded exact computation) -------- #
    # When set and S > chunk, attention runs in query chunks and SSM/mLSTM
    # scans run chunk-recurrently (exact; bounds temps for 32k+ prefill).
    attn_chunk: Optional[int] = None
    ssm_chunk: Optional[int] = None
    # --- numerics --------------------------------------------------------- #
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    # Adam m/v dtype; the 398B arch needs bf16 states to fit HBM (DESIGN §8).
    opt_state_dtype: str = "float32"
    # --- notes ------------------------------------------------------------- #
    source: str = ""
    sub_quadratic: bool = False  # eligible for long_500k decode

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def remainder(self) -> Tuple[Sublayer, ...]:
        return self.period[: self.n_layers % len(self.period)]

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=max(len(self.period), overrides.pop("n_layers", len(self.period))),
            d_model=overrides.pop("d_model", 64),
            n_heads=overrides.pop("n_heads", 4),
            n_kv_heads=overrides.pop(
                "n_kv_heads", min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1
            ),
            head_dim=overrides.pop("head_dim", 16),
            d_ff=overrides.pop("d_ff", 128 if self.d_ff else 0),
            vocab_size=overrides.pop("vocab_size", 256),
            n_enc_layers=overrides.pop(
                "n_enc_layers", min(self.n_enc_layers, 2)
            ),
            window=overrides.pop("window", 8 if self.window else None),
            param_dtype="float32",
        )
        if self.moe is not None:
            # ample capacity: keeps reduced-config decode/train consistent
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2), pad_to=1, capacity_factor=8.0,
            )
        if self.mrope_sections is not None:
            hd = changes.get("head_dim", 16)
            half = hd // 2
            r = 3 * half // 8
            changes["mrope_sections"] = (half - 2 * r, r, r)
        if self.mamba is not None:
            changes["mamba"] = dataclasses.replace(self.mamba, d_state=8, expand=2)
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


ARCH_IDS = [
    "jamba_1p5_large_398b",
    "qwen2_vl_72b",
    "qwen2_moe_a2p7b",
    "olmoe_1b_7b",
    "whisper_large_v3",
    "minitron_8b",
    "gemma3_4b",
    "gemma2_2b",
    "gemma_2b",
    "xlstm_125m",
]


def get_config(name: str) -> ModelConfig:
    name = name.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG


def all_configs():
    return {n: get_config(n) for n in ARCH_IDS}
