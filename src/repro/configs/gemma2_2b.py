"""Gemma-2-2B [arXiv:2408.00118].

Alternating local(4096):global attention, attention- and logit-softcap,
head_dim 256, GeGLU, sqrt(d) embedding scaling.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    period=(("local", "mlp"), ("attn", "mlp")),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    ffn_act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2408.00118",
)
