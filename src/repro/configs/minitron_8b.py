"""Minitron-8B (pruned Nemotron-4) [arXiv:2407.14679; hf].

Squared-ReLU MLP (2-matrix), GQA kv=8, untied 256k embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    period=(("attn", "mlp"),),
    ffn_act="relu2",
    rope_theta=1e4,
    tie_embeddings=False,
    source="arXiv:2407.14679",
)
