"""OLMoE-1B-7B (6.9B total / 1.3B active) [arXiv:2409.02060; hf].

64 experts, top-8, QK-norm.
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    period=(("attn", "moe"),),
    moe=MoEConfig(n_experts=64, top_k=8, pad_to=16),
    ffn_act="swiglu",
    qk_norm=True,
    tie_embeddings=False,
    source="arXiv:2409.02060",
)
