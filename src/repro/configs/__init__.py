"""One config module per assigned architecture (+ registry in base)."""

from .base import ARCH_IDS, MambaConfig, ModelConfig, MoEConfig, all_configs, get_config

__all__ = ["ARCH_IDS", "MambaConfig", "ModelConfig", "MoEConfig", "all_configs", "get_config"]
