"""Gemma-3-4B [hf:google/gemma-3 family].

5:1 local:global attention (window 1024), head_dim 256, QK-norm, GeGLU,
sqrt(d) embedding scaling, 262k vocab.  34 layers = 5 full periods of 6
plus a 4-local remainder.  Sliding-window layers make long-context decode
sub-quadratic in cache size (long_500k eligible).
"""

from .base import ModelConfig

_PERIOD = (("local", "mlp"),) * 5 + (("attn", "mlp"),)

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    period=_PERIOD,
    window=1024,
    qk_norm=True,
    ffn_act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=1e6,
    sub_quadratic=True,
    source="hf:google/gemma-3-1b-pt (scaled)",
)
