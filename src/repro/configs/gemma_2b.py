"""Gemma-2B [arXiv:2403.08295].

MQA (single KV head), head_dim 256, GeGLU, d_ff 16384 (wide), sqrt(d)
embedding scaling, tied 256k embeddings.  Pure full attention.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    period=(("attn", "mlp"),),
    ffn_act="geglu",
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2403.08295",
)
