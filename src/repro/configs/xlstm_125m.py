"""xLSTM-125M [arXiv:2405.04517].

sLSTM + mLSTM blocks at 1:3 (period [m, m, m, s]); blocks carry their own
up/down projections so there is no separate FFN (d_ff = 0).  Recurrent
decode state is O(1) per token (long_500k eligible).
"""

from .base import ModelConfig

_PERIOD = (("mlstm", "none"),) * 3 + (("slstm", "none"),)

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    period=_PERIOD,
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.04517",
)
