"""Hartree–Fock SCF on the Pariser–Parr–Pople model (paper §3.3.3, §5.3).

The PPP Hamiltonian for a 1-D chain of ``n`` sites (one orthogonal basis
function per site, S = I): core Hamiltonian with nearest-neighbour hopping
``-t``; two-electron integrals in the Ohno parameterization

    gamma_{mu nu} = U / sqrt(1 + (U * R_{mu nu})^2),     R in units of the
    lattice spacing, so gamma_{mu mu} = U.

Closed-shell restricted HF fixed-point map F: P -> P' (paper steps 1-3):

    F(P)  = H + diag(gamma @ diag(P)) - 1/2 * P ⊙ gamma     (Fock build)
    F C = C eps                                              (eigh, S = I)
    P'    = 2 * C_occ C_occ^T                                (density)

U/|t| controls the SCF Jacobian's spectral radius: small => rapid
contraction; ~2.5 => multiple fixed points (async convergence becomes
stochastic, paper Fig. 8); >= 4 => even synchronous DIIS struggles.

The state is the flattened density matrix; workers own row-blocks, evaluate
the *full* SCF map on the stale snapshot and return only their rows (paper
§3.3.3) — evaluation-level perturbation, coupling density 1.  The
coordinator symmetrizes after every application (``project``) and uses the
DIIS commutator residual ``[F(P), P]`` for acceleration and convergence.
"""

from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FixedPointProblem, restrict

__all__ = ["PPPChain", "SCFProblem"]


class PPPChain:
    """PPP model for a 1-D chain at half filling (n even)."""

    def __init__(self, n_atoms: int = 8, U: float = 2.0, t: float = 1.0):
        assert n_atoms % 2 == 0, "half filling requires even n_atoms"
        self._ctor = dict(n_atoms=n_atoms, U=U, t=t)
        self.n = n_atoms
        self.U = U
        self.t = t
        self.n_occ = n_atoms // 2
        H = np.zeros((n_atoms, n_atoms))
        for i in range(n_atoms - 1):
            H[i, i + 1] = H[i + 1, i] = -t
        R = np.abs(np.arange(n_atoms)[:, None] - np.arange(n_atoms)[None, :])
        gamma = U / np.sqrt(1.0 + (U * R) ** 2)  # Ohno
        self.H = jnp.asarray(H)
        self.gamma = jnp.asarray(gamma)
        # Nuclear(core)-core repulsion of the +1 cores, constant shift.
        self.e_core = float(np.sum(np.triu(np.asarray(gamma), k=1)))

    # ------------------------------------------------------------------ #
    @functools.partial(jax.jit, static_argnums=0)
    def fock(self, P: jnp.ndarray) -> jnp.ndarray:
        J = jnp.diag(self.gamma @ jnp.diag(P))
        K = P * self.gamma
        return self.H + J - 0.5 * K

    @functools.partial(jax.jit, static_argnums=0)
    def scf_map(self, P: jnp.ndarray) -> jnp.ndarray:
        F = self.fock(P)
        _, C = jnp.linalg.eigh(F)
        Cocc = C[:, : self.n_occ]
        return 2.0 * Cocc @ Cocc.T

    @functools.partial(jax.jit, static_argnums=0)
    def commutator(self, P: jnp.ndarray) -> jnp.ndarray:
        F = self.fock(P)
        return F @ P - P @ F  # S = I

    @functools.partial(jax.jit, static_argnums=0)
    def electronic_energy(self, P: jnp.ndarray) -> jnp.ndarray:
        F = self.fock(P)
        return 0.5 * jnp.sum(P * (self.H + F))

    def energy(self, P: np.ndarray) -> float:
        Pm = jnp.asarray(P.reshape(self.n, self.n))
        return float(self.electronic_energy(Pm)) + self.e_core

    def core_guess(self) -> np.ndarray:
        _, C = jnp.linalg.eigh(self.H)
        Cocc = C[:, : self.n_occ]
        return np.asarray(2.0 * Cocc @ Cocc.T)


class UHFPPP:
    """Spin-unrestricted PPP Hartree-Fock (paper §3.3.3 map, UHF variant).

    The UHF energy landscape at intermediate U/|t| admits competing
    paramagnetic and spin-density-wave fixed points — the multistability
    regime of paper Fig. 8.  State: (P_up, P_dn) stacked.

        F_sigma = H + diag(gamma @ diag(P_up + P_dn)) - P_sigma ⊙ gamma
    """

    def __init__(self, chain: PPPChain):
        self.chain = chain
        self.n = chain.n
        self.n_occ = chain.n // 2  # S_z = 0: n/2 up + n/2 down electrons

    @functools.partial(jax.jit, static_argnums=0)
    def fock(self, Pu: jnp.ndarray, Pd: jnp.ndarray):
        c = self.chain
        J = jnp.diag(c.gamma @ jnp.diag(Pu + Pd))
        return c.H + J - Pu * c.gamma, c.H + J - Pd * c.gamma

    @functools.partial(jax.jit, static_argnums=0)
    def scf_map(self, Pu: jnp.ndarray, Pd: jnp.ndarray):
        Fu, Fd = self.fock(Pu, Pd)
        _, Cu = jnp.linalg.eigh(Fu)
        _, Cd = jnp.linalg.eigh(Fd)
        Pu2 = Cu[:, : self.n_occ] @ Cu[:, : self.n_occ].T
        Pd2 = Cd[:, : self.n_occ] @ Cd[:, : self.n_occ].T
        return Pu2, Pd2

    @functools.partial(jax.jit, static_argnums=0)
    def commutator(self, Pu, Pd):
        Fu, Fd = self.fock(Pu, Pd)
        return Fu @ Pu - Pu @ Fu, Fd @ Pd - Pd @ Fd

    def energy(self, Pu: np.ndarray, Pd: np.ndarray) -> float:
        c = self.chain
        Pu = jnp.asarray(Pu)
        Pd = jnp.asarray(Pd)
        Fu, Fd = self.fock(Pu, Pd)
        e = 0.5 * (jnp.sum((Pu + Pd) * c.H) + jnp.sum(Pu * Fu)
                   + jnp.sum(Pd * Fd))
        return float(e) + c.e_core


def _rebuild_scf(chain_kwargs, guess):
    """Factory for multi-interpreter executors (see ``factory_spec``)."""
    return SCFProblem(PPPChain(**chain_kwargs), guess=guess)


def _rebuild_uhf_scf(chain_kwargs, spin_seed):
    return UHFSCFProblem(PPPChain(**chain_kwargs), spin_seed=spin_seed)


class UHFSCFProblem(FixedPointProblem):
    """UHF-PPP as a partitioned fixed-point problem; state = (P_up | P_dn).

    Workers own row-blocks of BOTH spin densities; the coordinator
    symmetrizes each spin block (paper §3.3.3 'assembles, symmetrizes').
    The multistable regime (paper Fig. 8) lives here: paramagnetic vs
    spin-density-wave fixed points at intermediate U/|t|.
    """

    def __init__(self, chain: PPPChain, spin_seed: float = 0.05):
        self.uhf = UHFPPP(chain)
        self.chain = chain
        self.n_ao = chain.n
        self.n = 2 * chain.n * chain.n
        self.spin_seed = spin_seed

    def _split(self, x: np.ndarray):
        n = self.n_ao
        return (jnp.asarray(x[: n * n].reshape(n, n)),
                jnp.asarray(x[n * n:].reshape(n, n)))

    def initial(self) -> np.ndarray:
        P = np.asarray(self.chain.core_guess()) / 2.0
        alt = np.diag(0.5 * self.spin_seed * (-1.0) ** np.arange(self.n_ao))
        Pu, Pd = P + alt, P - alt
        return np.concatenate([Pu.reshape(-1), Pd.reshape(-1)])

    def full_map(self, x: np.ndarray) -> np.ndarray:
        Pu, Pd = self._split(x)
        Pu2, Pd2 = self.uhf.scf_map(Pu, Pd)
        return np.concatenate([np.asarray(Pu2).reshape(-1),
                               np.asarray(Pd2).reshape(-1)])

    def block_update(self, x: np.ndarray, indices: np.ndarray) -> np.ndarray:
        # Spin blocks concatenate two flat ranges, so a single slice rarely
        # applies — but uniform/greedy runs still benefit when it does.
        return restrict(self.full_map(x), indices)

    def default_blocks(self, p: int):
        n = self.n_ao
        bounds = np.linspace(0, n, p + 1).astype(int)
        blocks = []
        for i in range(p):
            rows = np.arange(bounds[i] * n, bounds[i + 1] * n)
            blocks.append(np.concatenate([rows, rows + n * n]))
        return blocks

    def project(self, x: np.ndarray) -> np.ndarray:
        Pu, Pd = self._split(x)
        Pu = 0.5 * (Pu + Pu.T)
        Pd = 0.5 * (Pd + Pd.T)
        return np.concatenate([np.asarray(Pu).reshape(-1),
                               np.asarray(Pd).reshape(-1)])

    def accel_residual(self, x: np.ndarray, g: np.ndarray) -> np.ndarray:
        Pu, Pd = self._split(x)
        Cu, Cd = self.uhf.commutator(Pu, Pd)
        return np.concatenate([np.asarray(Cu).reshape(-1),
                               np.asarray(Cd).reshape(-1)])

    def residual(self, x: np.ndarray) -> np.ndarray:
        return self.accel_residual(x, x)

    def residual_norm(self, x: np.ndarray) -> float:
        return float(np.linalg.norm(self.residual(x)))

    def energy(self, x: np.ndarray) -> float:
        Pu, Pd = self._split(x)
        return self.uhf.energy(np.asarray(Pu), np.asarray(Pd))

    def dependency_counts(self) -> None:
        return None  # dense coupling

    def factory_spec(self):
        return (_rebuild_uhf_scf, (self.chain._ctor, self.spin_seed), {})

    def reference_energy(self, max_iter: int = 400, tol: float = 1e-11) -> float:
        """Lowest UHF energy over PM / SDW(+) / SDW(-) DIIS starts."""
        from repro.core.anderson import AndersonConfig, AndersonState

        best = np.inf
        for seed in (0.0, self.spin_seed, -self.spin_seed, 4 * self.spin_seed):
            save = self.spin_seed
            self.spin_seed = seed
            x = self.initial()
            self.spin_seed = save
            st = AndersonState(AndersonConfig(m=8, beta=1.0, reg=1e-12))
            for _ in range(max_iter):
                g = self.full_map(x)
                st.push(x, g, self.accel_residual(x, g))
                cand = st.propose()
                x = self.project(cand if cand is not None else g)
                if self.residual_norm(x) < tol:
                    break
            if self.residual_norm(x) < 1e-6:
                best = min(best, self.energy(x))
        return best


class SCFProblem(FixedPointProblem):
    """SCF as a partitioned fixed-point problem on the flattened density."""

    def __init__(self, chain: PPPChain, guess: Optional[np.ndarray] = None):
        self.chain = chain
        self.n_ao = chain.n
        self.n = chain.n * chain.n
        self._guess = guess

    # ----------------------------------------------------------------- #
    def initial(self) -> np.ndarray:
        P0 = self.chain.core_guess() if self._guess is None else self._guess
        return np.asarray(P0).reshape(-1).astype(np.float64)

    def full_map(self, x: np.ndarray) -> np.ndarray:
        P = jnp.asarray(x.reshape(self.n_ao, self.n_ao))
        return np.asarray(self.chain.scf_map(P)).reshape(-1)

    def block_update(self, x: np.ndarray, indices: np.ndarray) -> np.ndarray:
        # Worker: full SCF map on the stale snapshot, return owned rows only
        # (row blocks are flat consecutive ranges: restrict via a slice).
        return restrict(self.full_map(x), indices)

    def default_blocks(self, p: int) -> List[np.ndarray]:
        # Row blocks of the density matrix, as flat index ranges.
        bounds = np.linspace(0, self.n_ao, p + 1).astype(int)
        return [
            np.arange(bounds[i] * self.n_ao, bounds[i + 1] * self.n_ao)
            for i in range(p)
        ]

    def project(self, x: np.ndarray) -> np.ndarray:
        """Coordinator-side symmetrization (paper: 'assembles, symmetrizes')."""
        P = x.reshape(self.n_ao, self.n_ao)
        return (0.5 * (P + P.T)).reshape(-1)

    # ----------------------------------------------------------------- #
    def accel_residual(self, x: np.ndarray, g: np.ndarray) -> np.ndarray:
        """DIIS commutator error FPS - SPF (S = I) at the current iterate."""
        P = jnp.asarray(x.reshape(self.n_ao, self.n_ao))
        return np.asarray(self.chain.commutator(P)).reshape(-1)

    def residual(self, x: np.ndarray) -> np.ndarray:
        P = jnp.asarray(x.reshape(self.n_ao, self.n_ao))
        return np.asarray(self.chain.commutator(P)).reshape(-1)

    def residual_norm(self, x: np.ndarray) -> float:
        return float(np.linalg.norm(self.residual(x)))

    def energy(self, x: np.ndarray) -> float:
        return self.chain.energy(x)

    # --- structure: dense coupling through the two-electron integrals --- #
    def dependency_counts(self) -> None:
        return None  # dense => coupling density 1 (see core.coupling)

    def factory_spec(self):
        guess = None if self._guess is None else np.asarray(self._guess)
        return (_rebuild_scf, (self.chain._ctor, guess), {})

    # --- reference ------------------------------------------------------ #
    def reference_solution(self, max_iter: int = 500, tol: float = 1e-12,
                           diis_m: int = 8) -> np.ndarray:
        """Synchronous DIIS from the core guess (the paper's sync baseline)."""
        from repro.core.anderson import AndersonConfig, AndersonState

        x = self.initial()
        st = AndersonState(AndersonConfig(m=diis_m, beta=1.0, reg=1e-12))
        for _ in range(max_iter):
            g = self.full_map(x)
            st.push(x, g, self.accel_residual(x, g))
            cand = st.propose()
            x_new = cand if cand is not None else g
            x_new = self.project(x_new)
            if self.residual_norm(x_new) < tol:
                return x_new
            x = x_new
        return x
