"""Asynchronous value iteration on Garnet MDPs (paper §3.3.2, §5.2).

The Bellman optimality operator

    (T V)(s) = max_a [ R(s,a) + gamma * sum_b P(s'_b | s,a) V(s'_b) ]

is a gamma-contraction in the sup norm.  Garnet(S, A, b) random MDPs
(Archibald et al. 1995): each (s, a) has ``b`` distinct successor states
with stick-breaking probabilities and uniform(0,1) rewards.

Workers own state blocks; each update is the *full map component* evaluated
on the (stale) snapshot — the evaluation-level-perturbation mechanism that
lets Anderson survive asynchrony (paper §3.5).

A :class:`PolicyEvaluationProblem` (linear, T_pi V = r_pi + gamma P_pi V)
isolates the max-operator non-smoothness from the l2/linf norm mismatch.
A :class:`GridWorldMDP` provides a known-optimal-policy validation target.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import (
    DeviceBlockPlan,
    FixedPointProblem,
    as_block_slice,
    restrict,
)

__all__ = [
    "GarnetMDP",
    "GridWorldMDP",
    "ValueIterationProblem",
    "PolicyEvaluationProblem",
]


@jax.jit
def _bellman(V, idx, probs, R, gamma):
    """(T V)(s) for all s: gather successors, expect, max over actions."""
    ev = jnp.einsum("sab,sab->sa", probs, V[idx])
    return jnp.max(R + gamma * ev, axis=1)


@jax.jit
def _bellman_policy(V, idx, probs, R, gamma, pi):
    """(T_pi V)(s): expectation under a fixed policy (linear map)."""
    ev = jnp.einsum("sab,sab->sa", probs, V[idx])
    q = R + gamma * ev
    return jnp.take_along_axis(q, pi[:, None], axis=1)[:, 0]


class GarnetMDP:
    """Garnet(S, A, b) random MDP (Archibald/McKinnon/Thomas 1995)."""

    def __init__(self, S: int = 500, A: int = 4, b: int = 5, gamma: float = 0.95,
                 seed: int = 0, sample: str = "exact"):
        self.S, self.A, self.b, self.gamma = S, A, b, gamma
        self._ctor = dict(S=S, A=A, b=b, gamma=gamma, seed=seed,
                          sample=sample)
        rng = np.random.default_rng(seed)
        if sample == "fast":
            # Vectorized successor draw for large-S benchmarks: one
            # rng.integers call instead of S*A rng.choice calls.  Unlike
            # the exact recipe the b successors per (s, a) may repeat
            # (probability O(b^2/S) — negligible at benchmark scales);
            # the default "exact" path is untouched so every fixed-seed
            # trajectory stays bit-identical.
            idx = rng.integers(0, S, size=(S, A, b), dtype=np.int64)
            idx = idx.astype(np.int32)
        elif sample == "exact":
            idx = np.empty((S, A, b), dtype=np.int32)
            for s in range(S):
                for a in range(A):
                    idx[s, a] = rng.choice(S, size=b, replace=False)
        else:
            raise ValueError(f"unknown sample mode {sample!r}")
        # Stick-breaking transition probabilities (standard Garnet recipe).
        cuts = np.sort(rng.uniform(size=(S, A, b - 1)), axis=-1)
        probs = np.diff(np.concatenate(
            [np.zeros((S, A, 1)), cuts, np.ones((S, A, 1))], axis=-1), axis=-1)
        self.idx = jnp.asarray(idx)
        self.probs = jnp.asarray(probs)
        self.R = jnp.asarray(rng.uniform(size=(S, A)))

    def bellman(self, V: np.ndarray) -> np.ndarray:
        return np.asarray(_bellman(jnp.asarray(V), self.idx, self.probs, self.R,
                                   self.gamma))

    def q_values(self, V: np.ndarray) -> np.ndarray:
        ev = jnp.einsum("sab,sab->sa", self.probs, jnp.asarray(V)[self.idx])
        return np.asarray(self.R + self.gamma * ev)

    def greedy_policy(self, V: np.ndarray) -> np.ndarray:
        return np.argmax(self.q_values(V), axis=1)


class GridWorldMDP(GarnetMDP):
    """Deterministic grid navigation with a goal — known-optimal validation.

    ``g x g`` grid, 4 actions (N/S/E/W), step reward -1, absorbing goal at
    the top-left corner with reward 0.  Optimal V*(s) = -gamma-discounted
    Manhattan distance; computed in closed form for the tests.
    """

    def __init__(self, g: int = 10, gamma: float = 0.95):
        self.S, self.A, self.b, self.gamma = g * g, 4, 1, gamma
        self._ctor = dict(g=g, gamma=gamma)
        self.g = g
        S = self.S
        idx = np.zeros((S, 4, 1), dtype=np.int32)
        R = np.full((S, 4), -1.0)
        for s in range(S):
            r, c = divmod(s, g)
            moves = [(max(r - 1, 0), c), (min(r + 1, g - 1), c),
                     (r, max(c - 1, 0)), (r, min(c + 1, g - 1))]
            for a, (nr, nc) in enumerate(moves):
                idx[s, a, 0] = nr * g + nc
        goal = 0
        idx[goal, :, 0] = goal
        R[goal, :] = 0.0
        self.idx = jnp.asarray(idx)
        self.probs = jnp.asarray(np.ones((S, 4, 1)))
        self.R = jnp.asarray(R)

    def optimal_values(self) -> np.ndarray:
        """Closed form: V*(s) = -(1 - gamma^d(s)) / (1 - gamma)."""
        g, gamma = self.g, self.gamma
        V = np.zeros(self.S)
        for s in range(self.S):
            r, c = divmod(s, g)
            d = r + c
            V[s] = -(1.0 - gamma**d) / (1.0 - gamma)
        return V


@jax.jit
def _vi_block_step(v, vold, idx, probs, R, gamma):
    """Fused state-block Bellman backup + block-local inf-norm residual.

    ``v`` is the (possibly remapped) successor-value vector — the block's
    dependency closure when the device plane ships dependency slices, or
    the full iterate.  Same einsum/max arithmetic as :func:`_bellman`.
    """
    ev = jnp.einsum("sab,sab->sa", probs, v[idx])
    tv = jnp.max(R + gamma * ev, axis=1)
    return tv, jnp.max(jnp.abs(tv - vold))


class _VIDevicePlan(DeviceBlockPlan):
    """Device-resident VI state block.

    The block's transition rows (idx, probs, R) stay resident; per
    dispatch the plan consumes the block's *dependency closure* — the
    unique successor states its backups read, remapped once at build time
    via ``searchsorted`` — instead of the full iterate.  Garnet blocks
    whose closure approaches the full state space (dep > n/2) fall back
    to shipping all of x; the fused kernel still saves the full-map
    restriction (the host path evaluates T V at every state and throws
    away all but the block).
    """

    def __init__(self, problem: "ValueIterationProblem", s0: int, s1: int,
                 mode: str):
        mdp = problem.mdp
        self._mode = mode
        self._gamma = mdp.gamma
        idx_blk = np.asarray(mdp.idx)[s0:s1]
        dep = np.unique(idx_blk)
        if dep.size > problem.n // 2:
            self.needs = [slice(0, problem.n)]
            self._remap = mdp.idx[s0:s1]
        else:
            self.needs = [dep.astype(np.int64)]
            self._remap = jnp.asarray(
                np.searchsorted(dep, idx_blk).astype(np.int32))
        self._probs = mdp.probs[s0:s1]
        self._R = mdp.R[s0:s1]
        self._blk = None

    def refresh(self, block_values: np.ndarray) -> None:
        self._blk = jnp.asarray(np.asarray(block_values, dtype=np.float64))

    def step(self, *need_vals: np.ndarray):
        v = jnp.asarray(need_vals[0])
        if self._mode == "jnp":
            tv, norm = _vi_block_step(v, self._blk, self._remap,
                                      self._probs, self._R, self._gamma)
        elif self._mode in ("pallas", "interpret"):
            from repro.kernels import kernel_ops

            tv, norm = kernel_ops.bellman_block(
                self._remap, self._probs, self._R, v, self._blk,
                gamma=self._gamma, interpret=self._mode == "interpret")
        elif self._mode == "ref":
            from repro.kernels.ref import ref_bellman_block

            tv, norm = ref_bellman_block(
                np.asarray(self._remap), np.asarray(self._probs),
                np.asarray(self._R), np.asarray(v), np.asarray(self._blk),
                gamma=self._gamma)
            tv = jnp.asarray(tv)
        else:
            raise ValueError(f"unknown device_plane mode {self._mode!r}")
        self._blk = tv
        return np.asarray(tv), float(norm)


def _rebuild_vi(mdp_cls, mdp_kwargs):
    """Factory for multi-interpreter executors (see ``factory_spec``)."""
    return ValueIterationProblem(mdp_cls(**mdp_kwargs))


def _rebuild_policy_eval(mdp_cls, mdp_kwargs, policy):
    return PolicyEvaluationProblem(mdp_cls(**mdp_kwargs), policy=policy)


class ValueIterationProblem(FixedPointProblem):
    """V <- T V as a partitioned fixed-point problem."""

    def __init__(self, mdp: GarnetMDP):
        self.mdp = mdp
        self.n = mdp.S
        self._sol: Optional[np.ndarray] = None

    def initial(self) -> np.ndarray:
        return np.zeros(self.n)

    def full_map(self, x: np.ndarray) -> np.ndarray:
        return self.mdp.bellman(x)

    def block_update(self, x: np.ndarray, indices: np.ndarray) -> np.ndarray:
        # Each state's update IS the full map component at the stale snapshot
        # (evaluation-level perturbation, paper §3.5).  Contiguous state
        # blocks restrict via a slice (memcpy) instead of a gather.
        return restrict(self.full_map(x), indices)

    def residual_norm(self, x: np.ndarray) -> float:
        # linf: the Bellman operator contracts in the sup norm.
        return float(np.max(np.abs(self.residual(x))))

    def exact_solution(self) -> np.ndarray:
        if self._sol is None:
            V = np.zeros(self.n)
            for _ in range(200_000):
                V2 = self.full_map(V)
                if np.max(np.abs(V2 - V)) < 1e-13:
                    V = V2
                    break
                V = V2
            self._sol = V
        return self._sol

    def device_block_plan(self, indices, mode: str):
        sl = as_block_slice(indices)
        if sl is None:
            return None  # scattered selection: host path
        return _VIDevicePlan(self, sl.start, sl.stop, mode)

    def factory_spec(self):
        ctor = getattr(self.mdp, "_ctor", None)
        if ctor is None:
            return None
        return (_rebuild_vi, (type(self.mdp), ctor), {})

    # --- structure ------------------------------------------------------ #
    def dependency_counts(self) -> np.ndarray:
        idx = np.asarray(self.mdp.idx).reshape(self.n, -1)
        return np.asarray(
            [len(np.unique(np.append(row, i))) for i, row in enumerate(idx)],
            dtype=np.int64,
        )

    def dependency_indices(self, i: int) -> np.ndarray:
        row = np.asarray(self.mdp.idx)[i].reshape(-1)
        return np.unique(np.append(row, i))


class PolicyEvaluationProblem(ValueIterationProblem):
    """Linear fixed point V = r_pi + gamma P_pi V (no max operator).

    Anderson applies cleanly via the Walker–Ni GMRES equivalence while the
    linf contraction remains — isolates non-smoothness from norm mismatch.
    """

    def __init__(self, mdp: GarnetMDP, policy: Optional[np.ndarray] = None):
        super().__init__(mdp)
        if policy is None:
            V_star = ValueIterationProblem(mdp).exact_solution()
            policy = mdp.greedy_policy(V_star)
        self.policy = jnp.asarray(policy.astype(np.int32))

    def factory_spec(self):
        ctor = getattr(self.mdp, "_ctor", None)
        if ctor is None:
            return None
        return (_rebuild_policy_eval,
                (type(self.mdp), ctor, np.asarray(self.policy)), {})

    def full_map(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(_bellman_policy(
            jnp.asarray(x), self.mdp.idx, self.mdp.probs, self.mdp.R,
            self.mdp.gamma, self.policy))

    def device_block_plan(self, indices, mode: str):
        # The fused kernel computes the max backup; the policy backup is a
        # different operator — host path only.
        return None

    def exact_solution(self) -> np.ndarray:
        if self._sol is None:
            # Direct linear solve of (I - gamma P_pi) V = r_pi.
            S = self.n
            idx = np.asarray(self.mdp.idx)
            probs = np.asarray(self.mdp.probs)
            R = np.asarray(self.mdp.R)
            pi = np.asarray(self.policy)
            P = np.zeros((S, S))
            r = np.empty(S)
            for s in range(S):
                a = pi[s]
                np.add.at(P[s], idx[s, a], probs[s, a])
                r[s] = R[s, a]
            self._sol = np.linalg.solve(np.eye(S) - self.mdp.gamma * P, r)
        return self._sol
