"""The paper's three fixed-point testbeds, in JAX (float64).

- :mod:`repro.problems.jacobi`          — 2-D Laplacian block Jacobi (§3.3.1)
- :mod:`repro.problems.value_iteration` — Garnet MDP Bellman / policy eval (§3.3.2)
- :mod:`repro.problems.scf`             — PPP-model Hartree–Fock SCF (§3.3.3)

Numerical fidelity of the paper's experiments (SCF to 1e-14 eV, Jacobi to
1e-6 on a rho=0.9995 map) requires float64, so importing this package
enables JAX x64 mode.  LM model code (:mod:`repro.models`) uses explicit
dtypes throughout and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

from .jacobi import JacobiProblem  # noqa: E402
from .value_iteration import (  # noqa: E402
    GarnetMDP,
    GridWorldMDP,
    PolicyEvaluationProblem,
    ValueIterationProblem,
)
from .scf import PPPChain, SCFProblem, UHFPPP, UHFSCFProblem  # noqa: E402

__all__ = [
    "JacobiProblem",
    "GarnetMDP",
    "GridWorldMDP",
    "PolicyEvaluationProblem",
    "ValueIterationProblem",
    "PPPChain",
    "SCFProblem",
    "UHFPPP",
    "UHFSCFProblem",
]
