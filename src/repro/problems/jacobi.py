"""Block Jacobi for the 2-D Laplacian (paper §3.3.1, §5.1).

``A x = b`` with the standard 5-point stencil on a ``g × g`` grid
(Dirichlet), Jacobi splitting ``A = D - (L + U)``: the fixed-point map is
``G(x) = D^{-1}(b + (L+U) x)`` with iteration matrix spectral radius
``rho = cos(pi / (g+1))`` (< 1, l2-contraction).

Workers own contiguous row-blocks of the grid and perform ``sweeps`` local
Jacobi sweeps per update with the block boundary frozen at the snapshot
(the paper's multi-sweep local solve; effective only above ~90% block
internal coupling, Fig. 3).

The full-grid sweep is backed by either pure jnp or the Pallas
``jacobi_stencil`` kernel (see :mod:`repro.kernels.jacobi_stencil`).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import (
    DeviceBlockPlan,
    FixedPointProblem,
    restrict,
)

__all__ = ["JacobiProblem"]


@functools.partial(jax.jit, static_argnames=("g",))
def _full_sweep(x: jnp.ndarray, b: jnp.ndarray, g: int) -> jnp.ndarray:
    """One global Jacobi sweep: x' = (b + sum of 4 neighbors) / 4."""
    xg = x.reshape(g, g)
    p = jnp.pad(xg, 1)
    nb = p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
    return ((b.reshape(g, g) + nb) / 4.0).reshape(-1)


@functools.partial(jax.jit, static_argnames=("g", "r0", "r1", "sweeps"))
def _block_sweeps(
    x: jnp.ndarray, b: jnp.ndarray, g: int, r0: int, r1: int, sweeps: int
) -> jnp.ndarray:
    """``sweeps`` local Jacobi sweeps on grid rows [r0, r1).

    The halo rows (r0-1 and r1) are frozen at the snapshot values — this is
    the worker-local solve whose stale boundary produces the paper's
    iterate-level corruption mechanism.
    """
    xg = x.reshape(g, g)
    bg = b.reshape(g, g)[r0:r1]
    top = xg[r0 - 1] if r0 > 0 else jnp.zeros(g, x.dtype)
    bot = xg[r1] if r1 < g else jnp.zeros(g, x.dtype)
    blk = xg[r0:r1]

    def one(blk, _):
        p = jnp.concatenate([top[None], blk, bot[None]], axis=0)
        p = jnp.pad(p, ((0, 0), (1, 1)))
        nb = p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
        return (bg + nb) / 4.0, None

    blk, _ = jax.lax.scan(one, blk, None, length=sweeps)
    return blk.reshape(-1)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def _halo_sweeps(blk: jnp.ndarray, top: jnp.ndarray, bot: jnp.ndarray,
                 bg: jnp.ndarray, sweeps: int):
    """:func:`_block_sweeps` against an already-resident block.

    Same arithmetic as ``_block_sweeps``'s scan body (so the device plane
    is bitwise-compatible with the host path on the same backend), but it
    consumes the (rows, g) block and two g-length halo rows directly
    instead of slicing the full iterate — the O(n) host array never
    crosses into the dispatch.  Also returns the fused block-local squared
    residual the data plane reports for free.
    """

    def one(b, _):
        p = jnp.concatenate([top[None], b, bot[None]], axis=0)
        p = jnp.pad(p, ((0, 0), (1, 1)))
        nb = p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
        return (bg + nb) / 4.0, None

    new, _ = jax.lax.scan(one, blk, None, length=sweeps)
    return new, jnp.sum((new - blk) ** 2)


class _JacobiDevicePlan(DeviceBlockPlan):
    """Device-resident whole-rows Jacobi block: per dispatch it consumes
    only the two g-length halo rows (r0-1 and r1) instead of the O(n)
    iterate — 32 KB instead of 32 MB at g=2048."""

    def __init__(self, problem: "JacobiProblem", r0: int, r1: int,
                 mode: str):
        g = problem.g
        self._g, self._r0, self._r1 = g, r0, r1
        self._rows = r1 - r0
        self._sweeps = problem.sweeps
        self._mode = mode
        self._bg = problem._b_j.reshape(g, g)[r0:r1]
        self._zeros = jnp.zeros(g, self._bg.dtype)
        self.needs = [s for s in (
            slice((r0 - 1) * g, r0 * g) if r0 > 0 else None,
            slice(r1 * g, (r1 + 1) * g) if r1 < g else None,
        ) if s is not None]
        self._blk = None
        # Multi-device hosts band-shard the resident block itself: each
        # local device owns rows/|devices| grid rows with an explicit
        # ppermute halo exchange per sweep (distributed/sharding.py).
        self._band_mesh = None
        if mode == "jnp" and len(jax.devices()) > 1:
            from repro.distributed.sharding import band_mesh

            self._band_mesh = band_mesh(self._rows)

    def refresh(self, block_values: np.ndarray) -> None:
        self._blk = jnp.asarray(
            np.asarray(block_values, dtype=np.float64).reshape(
                self._rows, self._g))

    def step(self, *need_vals: np.ndarray):
        halos = iter(need_vals)
        top = jnp.asarray(next(halos)) if self._r0 > 0 else self._zeros
        bot = jnp.asarray(next(halos)) if self._r1 < self._g else self._zeros
        if self._mode == "jnp":
            if self._band_mesh is not None:
                from repro.distributed.sharding import (
                    band_sharded_jacobi_sweeps)

                new, norm = band_sharded_jacobi_sweeps(
                    self._blk, top, bot, self._bg, sweeps=self._sweeps,
                    mesh=self._band_mesh)
            else:
                new, norm = _halo_sweeps(self._blk, top, bot, self._bg,
                                         self._sweeps)
        elif self._mode in ("pallas", "interpret"):
            from repro.kernels import kernel_ops

            new, norm = kernel_ops.jacobi_halo_sweeps(
                self._blk, top, bot, self._bg, sweeps=self._sweeps,
                interpret=self._mode == "interpret")
        elif self._mode == "ref":
            from repro.kernels.ref import ref_jacobi_halo_sweeps

            new, norm = ref_jacobi_halo_sweeps(
                np.asarray(self._blk), np.asarray(top), np.asarray(bot),
                np.asarray(self._bg), sweeps=self._sweeps)
            new = jnp.asarray(new)
        else:
            raise ValueError(f"unknown device_plane mode {self._mode!r}")
        self._blk = new
        return np.asarray(new).ravel(), float(norm)


@functools.partial(jax.jit, static_argnames=("g",))
def _apply_A(x: jnp.ndarray, g: int) -> jnp.ndarray:
    """y = A x for the 5-point Laplacian (diag 4, neighbors -1)."""
    xg = x.reshape(g, g)
    p = jnp.pad(xg, 1)
    nb = p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
    return (4.0 * xg - nb).reshape(-1)


class JacobiProblem(FixedPointProblem):
    """2-D Laplacian block Jacobi with multi-sweep local solves."""

    def __init__(
        self,
        grid: int = 100,
        sweeps: int = 10,
        seed: int = 0,
        backend: str = "jnp",  # "jnp" | "pallas"
    ):
        self.g = grid
        self.n = grid * grid
        self.sweeps = sweeps
        self.backend = backend
        self.seed = seed
        rng = np.random.default_rng(seed)
        # Random right-hand side: the solution A^{-1} b is dominated by the
        # smooth (slow) Laplacian modes, which is the regime in which the
        # paper's 100x100 run needs ~3,240 x 10-sweep rounds to reach an
        # absolute residual of 1e-6.
        self._b = rng.standard_normal(self.n)
        self._b_j = jnp.asarray(self._b)
        self._x_star: Optional[np.ndarray] = None

    # ----------------------------------------------------------------- #
    def initial(self) -> np.ndarray:
        return np.zeros(self.n)

    def full_map(self, x: np.ndarray) -> np.ndarray:
        if self.backend == "pallas":
            from repro.kernels import jacobi_ops

            return np.asarray(jacobi_ops.jacobi_sweep(jnp.asarray(x), self._b_j, self.g))
        return np.asarray(_full_sweep(jnp.asarray(x), self._b_j, self.g))

    def block_update(self, x: np.ndarray, indices: np.ndarray) -> np.ndarray:
        r0, r1 = self._rows_of(indices)
        if r0 is not None:
            out = _block_sweeps(jnp.asarray(x), self._b_j, self.g, r0, r1, self.sweeps)
            return np.asarray(out)
        # Non-whole-rows selection (uniform/greedy): single-sweep restriction.
        return restrict(self.full_map(x), indices)

    def _rows_of(self, indices: np.ndarray) -> Tuple[Optional[int], Optional[int]]:
        """Detect a contiguous whole-rows block; else (None, None)."""
        i0, i1 = int(indices[0]), int(indices[-1]) + 1
        if i1 - i0 != len(indices) or i0 % self.g or i1 % self.g:
            return None, None
        if len(indices) > 1 and indices[1] - indices[0] != 1:
            return None, None
        return i0 // self.g, i1 // self.g

    def device_block_plan(self, indices, mode: str):
        r0, r1 = self._rows_of(np.asarray(indices))
        if r0 is None:
            return None  # not a whole-rows block: host path
        return _JacobiDevicePlan(self, r0, r1, mode)

    def factory_spec(self):
        return (JacobiProblem, (), dict(grid=self.g, sweeps=self.sweeps,
                                        seed=self.seed, backend=self.backend))

    # ----------------------------------------------------------------- #
    def residual(self, x: np.ndarray) -> np.ndarray:
        return self._b - np.asarray(_apply_A(jnp.asarray(x), self.g))

    def residual_norm(self, x: np.ndarray) -> float:
        # Absolute 2-norm, matching the paper's convergence criterion.
        return float(np.linalg.norm(self.residual(x)))

    def exact_solution(self) -> np.ndarray:
        if self._x_star is None:
            import scipy.sparse as sp
            import scipy.sparse.linalg as spla

            g = self.g
            lap1d = sp.diags([-1.0, 2.0, -1.0], [-1, 0, 1], shape=(g, g))
            eye = sp.identity(g)
            A = (sp.kron(lap1d, eye) + sp.kron(eye, lap1d)).tocsc()
            self._x_star = spla.spsolve(A, self._b)
        return self._x_star

    # --- structure (coupling, paper §3.5) ------------------------------ #
    def dependency_counts(self) -> np.ndarray:
        counts = np.full(self.n, 5, dtype=np.int64)  # self + 4 neighbors
        grid_idx = np.arange(self.n).reshape(self.g, self.g)
        counts[grid_idx[0, :]] -= 1
        counts[grid_idx[-1, :]] -= 1
        counts[grid_idx[:, 0]] -= 1
        counts[grid_idx[:, -1]] -= 1
        return counts

    def dependency_indices(self, i: int) -> np.ndarray:
        r, c = divmod(i, self.g)
        deps = [i]
        if r > 0:
            deps.append(i - self.g)
        if r < self.g - 1:
            deps.append(i + self.g)
        if c > 0:
            deps.append(i - 1)
        if c < self.g - 1:
            deps.append(i + 1)
        return np.asarray(deps)

    # --- analysis helpers ---------------------------------------------- #
    @property
    def spectral_radius(self) -> float:
        return float(np.cos(np.pi / (self.g + 1)))
