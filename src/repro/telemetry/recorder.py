"""Ring-buffer telemetry recorder: typed spans + metric series.

Design constraints, in order:

1. **Zero cost when off.**  The recorder only exists when
   ``RunConfig.telemetry`` is set; every hot-path hook in the engine is a
   single ``if coord.telemetry is not None`` guard (the exact pattern the
   chaos tracer and autoscale probe already use), and the recorder never
   consumes rng or touches iterate floats, so the virtual goldens stay
   byte-identical with telemetry off *or on*.
2. **Lock-light when on.**  Emits append to ``collections.deque`` ring
   buffers (drop-oldest beyond ``TelemetryConfig.ring_size``, with a
   ``dropped`` counter so truncation is never silent) under one tiny
   internal lock; the thread backend emits almost entirely under the
   coordinator lock anyway, and process/ray workers batch their spans
   over the existing result channels instead of sharing the recorder.
3. **One clock per capture.**  Spans carry the *backend's* clock (virtual
   seconds on the virtual backend, ``elapsed()`` wall seconds on the real
   ones) installed via :meth:`TelemetryRecorder.install_clock` /
   :meth:`set_time`; host-side (perf_counter) durations ride along in
   span args where the two differ (inline fires on virtual time).

Span taxonomy (``SPAN_KINDS``) and metric registry (``METRICS``) are the
single source of truth: ``tools/docs_check.py`` asserts the README
telemetry table matches ``METRICS`` and that every
:data:`repro.chaos.scenario.EVENT_KINDS` entry and every trace-event
kind has a mapping here (``SCENARIO_SPAN_MAP`` / ``TRACE_SPAN_MAP``), so
an event kind can never be silently uninstrumented.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "METRICS",
    "SCENARIO_SPAN_MAP",
    "SPAN_KINDS",
    "TRACE_SPAN_MAP",
    "TelemetryCapture",
    "TelemetryConfig",
    "TelemetryRecorder",
    "as_telemetry_config",
    "worker_lane",
]

TELEMETRY_VERSION = 1

#: Span kinds -> what the span covers.  ``docs_check`` asserts every
#: trace-event kind and scenario-event kind maps into this taxonomy.
SPAN_KINDS: Dict[str, str] = {
    "task": "one worker task: dispatch -> compute -> arrival, with "
            "disposition (applied/filtered/crash/preempt_discard) and "
            "applied staleness",
    "compute": "worker-side kernel evaluation only (process/ray workers "
               "measure it locally and ship batches over the result "
               "channel; anchored at the parent's receive clock)",
    "fire": "accel begin -> feed -> commit window, with the commit "
            "verdict (accept/fallback/discard/partial) and pin mode",
    "record": "residual record: evaluation -> history append",
    "eval": "one offloaded evaluation item (full-map or residual norm) "
            "served by a worker/eval thread",
    "checkpoint": "checkpoint capture + atomic write",
    "restore": "checkpoint restore into a fresh coordinator (instant)",
    "sdc_screen": "SDC guard rejection of one arriving block (instant)",
    "serve": "serve-layer request: admission -> dispatch -> finish, with "
             "tenant and queueing delay",
    "scenario": "scripted or controller-issued scenario event (instant)",
    "restart": "worker crash-restart rejoin (instant)",
}

#: Metric series -> meaning.  The README telemetry table must list
#: exactly these names (enforced by ``tools/docs_check.py``).
METRICS: Dict[str, str] = {
    "staleness": "applied-update staleness histogram (value -> count)",
    "residual": "residual norm vs backend clock, one point per record",
    "busy_frac": "coordinator busy fraction over time (busy_s / t; "
                 "host-clock fraction on the virtual backend, where "
                 "coordinator work is free in virtual time)",
    "pool_leases": "outstanding leases on this run's warm worker pool "
                   "at acquire time (process backend)",
    "pool_respawns": "times this run's pool family had to be rebuilt "
                     "from scratch (0 = every run rode one warm pool)",
    "queue_depth": "serve-layer pending request queue depth over time",
}

#: Every ``repro.chaos.scenario.EVENT_KINDS`` entry maps to a span kind.
SCENARIO_SPAN_MAP: Dict[str, str] = {
    "set_profile": "scenario",
    "preempt": "scenario",
    "join": "scenario",
    "pause": "scenario",
    "resume": "scenario",
    "coordinator_crash": "scenario",
}

#: Every ``repro.chaos.trace`` event kind maps to a span kind, so a
#: trace-captured run and a telemetry capture describe the same events.
TRACE_SPAN_MAP: Dict[str, str] = {
    "dispatch": "task",
    "arrival": "task",
    "restart": "restart",
    "fire": "fire",
    "record": "record",
    "offload": "eval",
    "scenario": "scenario",
}


def worker_lane(worker: int, gen: int = 0) -> str:
    """Timeline lane for one worker *incarnation*.

    A preempted worker's rejoin gets a fresh lane (``w3#r1``), so
    evictions show as a lane that simply ends — the gap the paper's
    straggler/preemption story is about is visible, not averaged away.
    """
    return f"w{worker}" if gen == 0 else f"w{worker}#r{gen}"


@dataclass
class TelemetryConfig:
    """Knobs for one recorder (``RunConfig.telemetry`` accepts this or
    ``True`` for all-defaults)."""

    ring_size: int = 65536  # max retained events; oldest dropped beyond
    series_size: int = 4096  # max points per metric series
    series_every: int = 16  # busy-frac sampling cadence, in arrival ticks
    worker_batch: int = 32  # process/ray worker-side span batch size

    def __post_init__(self) -> None:
        if self.ring_size < 1:
            raise ValueError("ring_size must be >= 1")
        if self.series_size < 1:
            raise ValueError("series_size must be >= 1")
        if self.series_every < 1:
            raise ValueError("series_every must be >= 1")
        if self.worker_batch < 1:
            raise ValueError("worker_batch must be >= 1")


def as_telemetry_config(knob) -> TelemetryConfig:
    """Normalize the ``RunConfig.telemetry`` knob (``True`` or a config)."""
    if isinstance(knob, TelemetryConfig):
        return knob
    if knob is True:
        return TelemetryConfig()
    raise TypeError(
        f"telemetry must be None, True, or a TelemetryConfig, got {knob!r}")


@dataclass
class TelemetryCapture:
    """One finished capture: meta + event ring + series + summary.

    JSON-serializable end to end; :mod:`repro.telemetry.export` renders
    it and ``repro.launch.run_report`` reads it back from disk.
    """

    meta: dict = field(default_factory=dict)
    events: List[dict] = field(default_factory=list)
    series: Dict[str, list] = field(default_factory=dict)
    summary: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return {"version": TELEMETRY_VERSION, "meta": self.meta,
                "events": self.events, "series": self.series,
                "summary": self.summary}

    @classmethod
    def from_dict(cls, d: dict) -> "TelemetryCapture":
        if d.get("version", TELEMETRY_VERSION) != TELEMETRY_VERSION:
            raise ValueError(
                f"unsupported telemetry version {d.get('version')!r}")
        return cls(meta=dict(d.get("meta", {})),
                   events=list(d.get("events", [])),
                   series=dict(d.get("series", {})),
                   summary=dict(d.get("summary", {})))

    def save(self, path: str) -> str:
        import json

        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path

    @classmethod
    def load(cls, path: str) -> "TelemetryCapture":
        import json

        with open(path) as f:
            return cls.from_dict(json.load(f))


def _percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of a sorted sequence (0 if empty)."""
    if not sorted_vals:
        return 0.0
    idx = int(round(q * (len(sorted_vals) - 1)))
    return float(sorted_vals[idx])


class TelemetryRecorder:
    """Collects spans and metric series for one run (or one service).

    Emit paths never raise on full buffers — the oldest event drops and
    ``dropped`` counts it.  All public emit methods are thread-safe.
    """

    def __init__(self, cfg: Optional[TelemetryConfig] = None,
                 meta: Optional[dict] = None, n_workers: int = 1):
        self.cfg = cfg or TelemetryConfig()
        self.meta: dict = dict(meta or {})
        self._lock = threading.Lock()
        self.events: deque = deque(maxlen=self.cfg.ring_size)
        self.series: Dict[str, deque] = {}
        self.dropped = 0
        self.span_counts: Dict[str, int] = {}
        # Applied-staleness: exact histogram (small int keys) plus a
        # bounded recent window shared with the autoscale SignalProbe
        # (the ``telemetry_source`` adapter) so both read one buffer.
        self.staleness_hist: Dict[int, int] = {}
        self.staleness_window: deque = deque(
            maxlen=max(16, 4 * int(n_workers)))
        self.staleness_n = 0
        # Fire ledger (verdict -> count), fed by the fire spans.
        self.fires: Dict[str, int] = {}
        # In-flight task tracking: lane-keyed open dispatches.  The open
        # count is what lets inline fires report ``fire_window_arrivals``
        # (arrivals whose flight overlapped the fire — see satellite fix
        # in ``Coordinator.maybe_fire_accel``).
        self._open: Dict[Tuple[int, int], Tuple[float, Optional[int]]] = {}
        # Clocks: the backend installs its own (virtual or elapsed-wall);
        # until then ``now()`` is host seconds since construction.
        self._t0_host = time.perf_counter()
        self._now: Optional[Callable[[], float]] = None
        self._vt = 0.0
        # Host-side coordinator busy accounting (virtual inline runs have
        # no backend-metered busy_s; this is the recorder-side fallback).
        self.host_busy_s = 0.0
        self._busy_tick = 0

    # ---- clocks ------------------------------------------------------- #
    def install_clock(self, fn: Callable[[], float]) -> None:
        """Real backends: route ``now()`` to the loop's ``elapsed()``."""
        self._now = fn

    def set_time(self, t: float) -> None:
        """Virtual backend: pin ``now()`` to the event loop's clock."""
        self._vt = float(t)
        if self._now is not self._read_vt:
            self._now = self._read_vt

    def _read_vt(self) -> float:
        return self._vt

    def now(self) -> float:
        if self._now is not None:
            return self._now()
        return time.perf_counter() - self._t0_host

    def host_elapsed(self) -> float:
        return time.perf_counter() - self._t0_host

    def host_busy_frac(self) -> float:
        """Fraction of host time spent in coordinator-side math."""
        el = self.host_elapsed()
        return min(1.0, self.host_busy_s / el) if el > 0 else 0.0

    @contextmanager
    def host_busy(self):
        """Charge a host-clock coordinator section (inline fires/records
        on the virtual backend, where virtual time charges nothing)."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.host_busy_s += time.perf_counter() - t0

    # ---- raw emits ---------------------------------------------------- #
    def _emit(self, ev: dict) -> None:
        with self._lock:
            k = ev["k"]
            self.span_counts[k] = self.span_counts.get(k, 0) + 1
            if len(self.events) == self.events.maxlen:
                self.dropped += 1
            self.events.append(ev)

    def span(self, kind: str, lane: str, t0: float, t1: float,
             **args) -> None:
        ev = {"k": kind, "lane": lane, "t0": float(t0),
              "t1": float(max(t0, t1))}
        if args:
            ev.update(args)
        self._emit(ev)

    def instant(self, kind: str, lane: str, t: Optional[float] = None,
                **args) -> None:
        ev = {"k": kind, "lane": lane,
              "t": float(self.now() if t is None else t)}
        if args:
            ev.update(args)
        self._emit(ev)

    def series_point(self, metric: str, t: float, value: float) -> None:
        with self._lock:
            s = self.series.get(metric)
            if s is None:
                s = self.series[metric] = deque(maxlen=self.cfg.series_size)
            s.append((float(t), float(value)))

    # ---- engine hooks ------------------------------------------------- #
    def observe_staleness(self, s: int) -> None:
        s = int(s)
        with self._lock:
            self.staleness_hist[s] = self.staleness_hist.get(s, 0) + 1
            self.staleness_window.append(s)
            self.staleness_n += 1

    def task_open(self, worker: int, t: Optional[float] = None,
                  gen: int = 0, block: Optional[int] = None) -> None:
        t = self.now() if t is None else float(t)
        with self._lock:
            self._open[(int(worker), int(gen))] = (t, block)

    def task_close(self, worker: int, t: Optional[float] = None,
                   disp: str = "applied", staleness: int = 0,
                   gen: int = 0) -> None:
        t = self.now() if t is None else float(t)
        with self._lock:
            entry = self._open.pop((int(worker), int(gen)), None)
        if entry is None:
            return  # truncated (e.g. a restore mid-flight): nothing to span
        t0, block = entry
        ev = {"k": "task", "lane": worker_lane(worker, gen),
              "t0": float(t0), "t1": float(max(t0, t)), "disp": disp,
              "s": int(staleness)}
        if block is not None:
            ev["b"] = int(block)
        self._emit(ev)

    @property
    def open_tasks(self) -> int:
        """Dispatches without an arrival yet (in-flight work)."""
        return len(self._open)

    def fire_span(self, t0: float, t1: float, verdict: str,
                  **args) -> None:
        with self._lock:
            self.fires[verdict] = self.fires.get(verdict, 0) + 1
        self.span("fire", "coord", t0, t1, v=verdict, **args)

    def maybe_sample_busy(self, t: float, busy_s: float) -> None:
        """Sample the busy-fraction series every ``series_every`` ticks.

        Real backends pass their metered ``coord.busy_s``; when that is
        zero (virtual inline runs, where coordinator work costs no
        virtual time) the host-clock fraction stands in — documented in
        docs/architecture.md, and what closes the inline observability
        gap for ``coordinator_busy_frac``.
        """
        self._busy_tick += 1
        if self._busy_tick % self.cfg.series_every:
            return
        frac = (min(1.0, busy_s / t) if (busy_s > 0.0 and t > 0.0)
                else self.host_busy_frac())
        self.series_point("busy_frac", t, frac)

    def merge_worker_batch(self, worker: int, batch, recv_t: float) -> None:
        """Fold a process/ray worker's shipped span batch into the ring.

        Workers measure compute with their own ``perf_counter`` (not
        comparable across processes), so each batch entry is
        ``(age_s, dur_s, kind)`` — *age* is how long before the batch
        send the span ended.  Anchoring ``t1 = recv_t - age`` keeps every
        lane on the parent's clock with only queue-transit skew.
        """
        for age, dur, kind in batch:
            t1 = max(0.0, float(recv_t) - float(age))
            t0 = max(0.0, t1 - float(dur))
            self.span(str(kind), worker_lane(worker), t0, t1, src="worker")

    # ---- summary / capture ------------------------------------------- #
    def staleness_percentile(self, q: float) -> float:
        """Exact nearest-rank percentile over the full histogram."""
        with self._lock:
            items = sorted(self.staleness_hist.items())
            n = self.staleness_n
        if n == 0:
            return 0.0
        rank = int(round(q * (n - 1)))
        seen = 0
        for value, count in items:
            seen += count
            if rank < seen:
                return float(value)
        return float(items[-1][0])

    def summary(self) -> dict:
        """Compact run digest (``RunResult.telemetry_summary``)."""
        with self._lock:
            busy = list(self.series.get("busy_frac", ()))
            counts = dict(self.span_counts)
            fires = dict(self.fires)
            dropped = self.dropped
            n = self.staleness_n
        return {
            "version": TELEMETRY_VERSION,
            "staleness_p50": self.staleness_percentile(0.50),
            "staleness_p95": self.staleness_percentile(0.95),
            "staleness_n": n,
            "busy_frac_tail": [round(v, 6) for _, v in busy[-8:]],
            "span_counts": counts,
            "fires": fires,
            "events_dropped": dropped,
        }

    def to_capture(self) -> TelemetryCapture:
        with self._lock:
            events = list(self.events)
            series = {k: [list(p) for p in v] for k, v in self.series.items()}
            series["staleness"] = [
                [int(s), int(c)]
                for s, c in sorted(self.staleness_hist.items())]
        return TelemetryCapture(meta=dict(self.meta), events=events,
                                series=series, summary=self.summary())

    def finalize(self, t: float, busy_s: float = 0.0) -> None:
        """Close out the capture at the run's final clock ``t``."""
        self.meta.setdefault("t_end", float(t))
        self.meta.setdefault("host_elapsed_s", self.host_elapsed())
        # One final busy sample so even short runs get a series point.
        frac = (min(1.0, busy_s / t) if (busy_s > 0.0 and t > 0.0)
                else self.host_busy_frac())
        self.series_point("busy_frac", float(t), frac)


def percentile_of(values, q: float) -> float:
    """Convenience for exporters/tests: nearest-rank of an iterable."""
    return _percentile(sorted(values), q)
