"""Telemetry exporters: JSONL, Chrome trace-event JSON, Prometheus text.

Three render targets for one :class:`~repro.telemetry.TelemetryCapture`:

- :func:`to_jsonl` — a line-delimited event stream (first line is the
  capture meta, then one JSON object per event, then one ``series``
  object), greppable and streamable;
- :func:`to_chrome_trace` — the Chrome trace-event format
  (``{"traceEvents": [...]}``; ``ph`` "X" complete spans in microseconds,
  "M" thread-name metadata, "i" instants, "C" counters), loadable in
  Perfetto / ``chrome://tracing``.  Every worker *incarnation* gets its
  own timeline lane, so a straggler shows as long task spans and an
  eviction as a lane that stops — :func:`validate_chrome_trace` is the
  schema check the tests and the bench gate share;
- :func:`to_prometheus` — text exposition for the serve layer
  (``SolverService.stats()`` counters plus wait-time quantiles from the
  serve spans when the service carries a recorder).
"""

from __future__ import annotations

import json
import re
from typing import Dict, List, Optional

from .recorder import TelemetryCapture, percentile_of

__all__ = [
    "to_chrome_trace",
    "to_jsonl",
    "to_prometheus",
    "validate_chrome_trace",
    "trace_lanes",
]

_US = 1e6  # capture clocks are seconds; trace-event ts/dur are microseconds


def to_jsonl(capture: TelemetryCapture) -> str:
    """Line-delimited JSON: meta, then events in order, then series."""
    lines = [json.dumps({"meta": capture.meta})]
    lines.extend(json.dumps(ev) for ev in capture.events)
    lines.append(json.dumps({"series": capture.series}))
    return "\n".join(lines) + "\n"


def _lane_order(lane: str):
    """Stable lane ordering: coord, then workers by (id, incarnation),
    then eval/serve lanes."""
    if lane == "coord":
        return (0, 0, 0, "")
    m = re.match(r"^w(\d+)(?:#r(\d+))?$", lane)
    if m:
        return (1, int(m.group(1)), int(m.group(2) or 0), "")
    return (2, 0, 0, lane)


def trace_lanes(capture: TelemetryCapture) -> List[str]:
    """Every lane referenced by the capture, in display order."""
    lanes = {ev["lane"] for ev in capture.events if "lane" in ev}
    return sorted(lanes, key=_lane_order)


def to_chrome_trace(capture: TelemetryCapture) -> dict:
    """Render a capture as a Chrome trace-event document.

    One pid (the run), one tid per lane, ``ts`` sorted non-decreasing
    (Perfetto does not require it; :func:`validate_chrome_trace` does, so
    exports are canonical).
    """
    lanes = trace_lanes(capture)
    tid = {lane: i for i, lane in enumerate(lanes)}
    events: List[dict] = []
    for lane in lanes:
        events.append({"ph": "M", "pid": 1, "tid": tid[lane],
                       "name": "thread_name", "args": {"name": lane}})
        events.append({"ph": "M", "pid": 1, "tid": tid[lane],
                       "name": "thread_sort_index",
                       "args": {"sort_index": tid[lane]}})
    body: List[dict] = []
    for ev in capture.events:
        lane = ev.get("lane", "coord")
        args = {k: v for k, v in ev.items()
                if k not in ("k", "lane", "t", "t0", "t1")}
        if "t0" in ev:
            body.append({"ph": "X", "pid": 1, "tid": tid.get(lane, 0),
                         "name": ev["k"], "cat": ev["k"],
                         "ts": ev["t0"] * _US,
                         "dur": max(0.0, (ev["t1"] - ev["t0"]) * _US),
                         "args": args})
        else:
            body.append({"ph": "i", "pid": 1, "tid": tid.get(lane, 0),
                         "name": ev["k"], "cat": ev["k"], "s": "t",
                         "ts": ev.get("t", 0.0) * _US, "args": args})
    for metric, points in capture.series.items():
        if metric == "staleness":
            continue  # a histogram, not a time series
        for t, v in points:
            body.append({"ph": "C", "pid": 1, "tid": 0, "name": metric,
                         "ts": t * _US, "args": {metric: v}})
    body.sort(key=lambda e: e["ts"])
    meta = dict(capture.meta)
    meta["staleness_hist"] = capture.series.get("staleness", [])
    return {"traceEvents": events + body, "displayTimeUnit": "ms",
            "otherData": meta}


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema check shared by the tests and the bench gate.

    Returns a list of problems (empty == valid): traceEvents present,
    every event carries pid/tid/ph, complete spans have ts >= 0 and
    dur >= 0 with non-decreasing ts, every referenced tid has exactly
    one thread_name metadata entry (one lane per worker incarnation).
    """
    errs: List[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    names: Dict[int, List[str]] = {}
    used_tids = set()
    last_ts = None
    for i, ev in enumerate(evs):
        for key in ("ph", "pid", "tid"):
            if key not in ev:
                errs.append(f"event {i} missing {key!r}")
        ph = ev.get("ph")
        if ph == "M":
            if ev.get("name") == "thread_name":
                names.setdefault(ev["tid"], []).append(
                    ev.get("args", {}).get("name", ""))
            continue
        used_tids.add(ev.get("tid"))
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i} has bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errs.append(f"event {i} ts {ts} < previous {last_ts} "
                        "(not monotone)")
        last_ts = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i} has bad dur {dur!r}")
    for tid, lane_names in names.items():
        if len(lane_names) != 1:
            errs.append(f"tid {tid} has {len(lane_names)} thread_name "
                        f"entries {lane_names} (want exactly one lane)")
    for tid in used_tids:
        if tid not in names:
            errs.append(f"tid {tid} has events but no thread_name lane")
    return errs


# --------------------------------------------------------------------- #
# Prometheus text exposition (serve layer)
# --------------------------------------------------------------------- #
_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})?\s[0-9eE+.\-]+$")


def _prom_escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"')


def to_prometheus(service, prefix: str = "repro_serve") -> str:
    """Text exposition for one :class:`repro.serve.SolverService`.

    Counters come from ``service.stats()``; wait/service-time quantiles
    from the service's serve spans when it carries a recorder
    (``ServiceConfig.telemetry=True``).
    """
    st = service.stats()
    out: List[str] = []

    def emit(name: str, kind: str, help_: str, samples) -> None:
        out.append(f"# HELP {prefix}_{name} {help_}")
        out.append(f"# TYPE {prefix}_{name} {kind}")
        for labels, value in samples:
            lab = ""
            if labels:
                inner = ",".join(f'{k}="{_prom_escape(str(v))}"'
                                 for k, v in sorted(labels.items()))
                lab = "{" + inner + "}"
            out.append(f"{prefix}_{name}{lab} {value:g}")

    emit("pending", "gauge", "queued requests awaiting dispatch",
         [({}, st["pending"])])
    emit("active", "gauge", "requests currently executing",
         [({}, st["active"])])
    emit("served_total", "counter", "completed requests per tenant",
         [({"tenant": t}, n) for t, n in sorted(st["served"].items())]
         or [({}, 0)])
    emit("failed_total", "counter", "requests that raised",
         [({}, st["failed"])])
    emit("rejected_total", "counter", "admission-control rejections",
         [({}, st["rejected"])])
    emit("crash_resumes_total", "counter",
         "coordinator crashes resumed from checkpoint",
         [({}, st["crash_resumes"])])
    tel = getattr(service, "telemetry", None)
    if tel is not None:
        spans = [ev for ev in tel.events if ev.get("k") == "serve"]
        waits = [ev.get("wait_s", 0.0) for ev in spans]
        totals = [ev["t1"] - ev["t0"] for ev in spans]
        if spans:
            emit("wait_seconds", "summary", "admission-to-dispatch delay",
                 [({"quantile": "0.5"}, percentile_of(waits, 0.5)),
                  ({"quantile": "0.95"}, percentile_of(waits, 0.95))])
            emit("request_seconds", "summary", "admission-to-finish latency",
                 [({"quantile": "0.5"}, percentile_of(totals, 0.5)),
                  ({"quantile": "0.95"}, percentile_of(totals, 0.95))])
        depth = tel.series.get("queue_depth")
        if depth:
            emit("queue_depth", "gauge",
                 "pending queue depth at the last sample",
                 [({}, depth[-1][1])])
    return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> Dict[str, float]:
    """Minimal exposition parser (the format check the tests use).

    Returns ``{metric{labels}: value}``; raises ValueError on any
    malformed non-comment line.
    """
    out: Dict[str, float] = {}
    for ln in text.splitlines():
        if not ln or ln.startswith("#"):
            continue
        if not _PROM_LINE.match(ln):
            raise ValueError(f"malformed exposition line: {ln!r}")
        name, value = ln.rsplit(" ", 1)
        out[name] = float(value)
    return out
