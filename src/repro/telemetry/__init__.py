"""Unified telemetry plane: spans, metric series, and timeline export.

One recorder (:class:`TelemetryRecorder`), owned by the coordinator when
``RunConfig.telemetry`` is set, collects typed spans (worker task
dispatch→arrival, accel fire begin→commit, offloaded evaluations,
checkpoint writes, SDC screens, serve admission→finish, scenario events)
and metric series (applied-staleness histogram, residual vs clock,
coordinator busy fraction, pool lease/respawn counts, serve queue depth)
from every backend and service layer.  Exporters (:mod:`.export`) render
a capture as a JSONL event stream, a Chrome trace-event JSON viewable in
Perfetto (one timeline lane per worker incarnation), or Prometheus text
exposition for the serve layer; ``python -m repro.launch.run_report``
renders a terminal summary from a captured run.

Zero-overhead when off: the default ``RunConfig.telemetry=None`` never
constructs a recorder, every hook is a single ``if ... is not None``
guard, and the recorder consumes no rng and touches no floats — the
virtual goldens stay byte-identical with telemetry off *or on*
(``tests/test_telemetry.py``).
"""

from .recorder import (
    METRICS,
    SCENARIO_SPAN_MAP,
    SPAN_KINDS,
    TRACE_SPAN_MAP,
    TelemetryCapture,
    TelemetryConfig,
    TelemetryRecorder,
    as_telemetry_config,
    worker_lane,
)
from .export import (
    to_chrome_trace,
    to_jsonl,
    to_prometheus,
    validate_chrome_trace,
)

__all__ = [
    "METRICS",
    "SCENARIO_SPAN_MAP",
    "SPAN_KINDS",
    "TRACE_SPAN_MAP",
    "TelemetryCapture",
    "TelemetryConfig",
    "TelemetryRecorder",
    "as_telemetry_config",
    "worker_lane",
    "to_chrome_trace",
    "to_jsonl",
    "to_prometheus",
    "validate_chrome_trace",
]
